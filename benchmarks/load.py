"""Canonical load-harness scenarios for BENCH_serving.json ("load" key).

The engine-facing machinery (arrival processes, shared-system-prompt
workload synthesis, the wall-clock replay driver, the report schema)
lives in ``repro.serving.load``; this module pins the benchmark
scenarios the CI artifact tracks:

  * ``poisson`` — exponential inter-arrival gaps at a fixed requests/s
    rate (the open-loop production model);
  * ``scripted`` — a deterministic burst trace (groups of simultaneous
    arrivals), the adversarial admission case and the friendly
    prefix-cache case, reused for the cache on/off comparison because
    its arrival times are reproducible.

Both draw from one mixed prompt/output-length workload in which most
prompts open with a shared system prompt. Standalone usage::

    PYTHONPATH=src python benchmarks/load.py [--requests N] [--rate RPS]

prints the per-scenario load reports as JSON; ``benchmarks/run.py
--only serving_load`` folds the same reports into BENCH_serving.json.
"""

from __future__ import annotations

import numpy as np

from repro.obs import Obs, SLOTargets
from repro.serving import Engine, EngineConfig
from repro.serving import load as load_mod

# one workload + engine shape shared by every scenario so the reports
# are comparable across arrival processes and cache settings
ENGINE = dict(lanes=4, num_slots=8, page_len=32, prefill_len=8,
              policy="chunked", chunk_len=4)
WORKLOAD = dict(prompt_len=(2, 12), out_len=(2, 8), n_system=2,
                system_len=8, p_shared=0.8, max_prompt=31)
# generous CI-box targets: order-of-magnitude serving regressions, not
# scheduler jitter on shared runners
TARGETS = SLOTargets(ttft_p99_s=2.0, token_p99_s=1.0)


def workload(vocab_size: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    spec = load_mod.WorkloadSpec(vocab_size=vocab_size, **WORKLOAD)
    return load_mod.synth_requests(spec, n, rng), rng


def scenario_traces(vocab_size: int, n: int, rate_rps: float,
                    seed: int = 0) -> dict:
    """The two tracked arrival processes over one drawn workload."""
    reqs, rng = workload(vocab_size, n, seed)
    return {
        "poisson": load_mod.make_trace(
            load_mod.poisson_arrivals(rate_rps, n, rng), reqs),
        "scripted": load_mod.make_trace(
            load_mod.burst_arrivals(n, burst=4, gap_s=0.02), reqs),
    }


def run_scenario(make_engine, trace, targets: SLOTargets = TARGETS) -> dict:
    """Warm the engine's compiled steps, replay the trace on the wall
    clock, and return (report, outputs)."""
    eng = make_engine()
    eng.add_request(list(trace[0].prompt), max_new=2)  # jit warmup
    eng.run()
    eng.obs.reset()
    res = load_mod.replay(eng, trace)
    rep = load_mod.load_report(eng, targets=targets, wall_s=res["wall_s"])
    return rep, res["out"]


def engine_factory(params, cfg, ctx, prefix_cache: bool = True,
                   enabled_obs: bool = True):
    def make():
        return Engine(params, cfg, ctx,
                      EngineConfig(prefix_cache=prefix_cache, **ENGINE),
                      obs=Obs(enabled=enabled_obs))
    return make


def main(argv=None) -> None:
    import argparse
    import json

    import jax

    from repro import configs as C
    from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
    from repro.models import lm

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="poisson arrival rate, requests/s")
    args = ap.parse_args(argv)

    cfg = C.tiny(C.ARCHS["starcoder2-7b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params = convert_params_mxfp4(params)
    ctx = RunCtx(shd=ShardingCtx(), quant="mxfp4_wonly", dense_attn_max=256)
    mk = engine_factory(params, cfg, ctx)
    out = {}
    for name, trace in scenario_traces(cfg.vocab_size, args.requests,
                                       args.rate).items():
        out[name], _ = run_scenario(mk, trace)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
