"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is the wall
time of the benchmark body on this host (CPU; TPU is the design target);
``derived`` carries the reproduced quantity vs the paper's value.

Accuracy-style benchmarks (Figs 5/6/7, Table 6) cannot use the paper's
datasets offline; they substitute (i) SQNR fidelity on realistic tensors
and (ii) end-task accuracy of a small model trained on a synthetic task --
reproducing the paper's *qualitative* claims (see EXPERIMENTS.md).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cimlib
from repro.core import digital, mx as mxlib
from repro.hwmodel import perf, specs as S
from repro.obs import sqnr_db as _sqnr_db

ROWS: list = []


def _run_meta() -> dict:
    """Provenance stamp for every BENCH_*.json artifact: numbers from CI
    boxes are only comparable within the same jax/backend/commit tuple."""
    import platform
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "git_sha": sha,
    }


def bench(fn):
    def run():
        t0 = time.time()
        derived = fn()
        ROWS.append((fn.__name__, (time.time() - t0) * 1e6, derived))

    run.__name__ = fn.__name__
    return run


def _setup_layer(seed=0, t=64, k=768, m=256, heavy_tail=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, k)).astype(np.float32)
    if heavy_tail:  # realistic activation outliers
        x *= 1.0 + 9.0 * (rng.random((t, k)) < 0.01)
    w = (rng.standard_normal((k, m)) * (1 / np.sqrt(k))).astype(np.float32)
    wq = mxlib.quantize_w(jnp.asarray(w))
    ref = np.asarray(
        mxlib.dequantize(mxlib.quantize(jnp.asarray(x)), out_len=k)
    ) @ np.asarray(mxlib.dequantize_w(wq))
    return jnp.asarray(x), wq, ref


@bench
def table1_io_penalty():
    outs = []
    for name, (pm, bm, p1) in S.PAPER_TABLE1.items():
        m_pm, m_bm, m_p1 = perf.io_penalty(S.WORKLOADS[name])
        outs.append(f"{name}:{m_pm:.2f}x[B={m_bm}]/{m_p1:.0f}x"
                    f" paper {pm}x[B={bm}]/{p1}x")
    return " | ".join(outs)


@bench
def table2_nvm_density():
    ctt = S.NVM["ctt"]
    adv = min(
        (S.NVM[o]["cell_f2"] / S.NVM[o]["max_bits"])
        / (ctt["cell_f2"] / ctt["max_bits"])
        for o in ("reram", "pcm", "feram")
    )
    return f"CTT density advantage >= {adv:.2f}x (paper >=1.5x)"


@bench
def table3_macro():
    return (
        f"768: {perf.macro_tops(768):.2f} TOPS (paper 20.02), "
        f"1024: {perf.macro_tops(1024):.2f} TOPS (paper 35.72), "
        f"density {perf.storage_density_kb_mm2(1024):.0f} kb/mm2 (paper ~1756)"
    )


@bench
def table4_systems():
    t4 = perf.table4()
    out = []
    for sysname, p in S.PAPER_TABLE4.items():
        m = t4[sysname]
        out.append(
            f"{sysname}: {m['tops']:.0f} TOPS (paper {p['tops']:.0f}), "
            f"{m['area_mm2']:.1f} mm2 (paper {p['area_mm2']}), "
            f"{m['power_w']:.0f} W (paper {p['power_w']:.0f})"
        )
    return " | ".join(out)


@bench
def table5_breakdown():
    base_ctt = perf.n_arrays(S.BASE) * perf.macro_area_mm2(768)
    large_ctt = perf.n_arrays(S.LARGE) * perf.macro_area_mm2(1024)
    return (
        f"CTT area base {base_ctt:.1f} mm2 (paper 256.30), "
        f"large {large_ctt:.1f} mm2 (paper 427.70)"
    )


@bench
def fig5_exponent_strategies():
    x, wq, ref = _setup_layer()
    out = []
    for cmb in (1, 2, 3, 4, 5):
        row = [f"CM={cmb}"]
        for label, cfg, needs_cal in (
            ("row0", cimlib.CIMConfig(adc_bits=None, cm_bits=cmb,
                                      strategy="row0", two_pass=False), False),
            ("row_opt", cimlib.CIMConfig(adc_bits=None, cm_bits=cmb,
                                         strategy="row_opt", two_pass=False),
             False),
            ("row_hist", cimlib.CIMConfig(adc_bits=None, cm_bits=cmb,
                                          two_pass=False), True),
            ("row_hist_2p", cimlib.CIMConfig(adc_bits=None, cm_bits=cmb,
                                             two_pass=True), True),
        ):
            calib = cimlib.calibrate_rowhist([x], wq, cfg) if needs_cal else None
            y, _ = cimlib.cim_linear(x, wq, cfg, calib)
            row.append(f"{label}={_sqnr_db(ref, y):.1f}dB")
        out.append(" ".join(row))
    return " | ".join(out)


@bench
def fig6_saturation():
    x, wq, _ = _setup_layer(seed=1)
    out = []
    for cmb in (0, 1, 2, 3, 4, 5):
        cfg = cimlib.CIMConfig(adc_bits=None, cm_bits=cmb, two_pass=True,
                               collect_stats=True)
        calib = cimlib.calibrate_rowhist([x], wq, cfg)
        _, st = cimlib.cim_linear(x, wq, cfg, calib)
        out.append(
            f"CM={cmb}: overflow={float(st['overflow_rate']):.3f} "
            f"underflow_p2={float(st['underflow_rate_p2']):.3f}"
        )
    # paper: overflow==0 under Row-Hist; underflow <=16% at CM>=3
    return " | ".join(out)


@bench
def fig7_adc_sweep():
    x, wq, ref = _setup_layer(seed=2)
    out = []
    for adc in (6, 8, 9, 10, 12, None):
        cfg = cimlib.CIMConfig(adc_bits=adc, cm_bits=3, two_pass=True)
        calib = cimlib.calibrate_rowhist([x], wq, cfg)
        y, _ = cimlib.cim_linear(x, wq, cfg, calib)
        out.append(f"ADC={adc}: {_sqnr_db(ref, y):.1f}dB")
    return " | ".join(out)  # saturates at 10b vs the no-ADC bound


@bench
def table6_accuracy_tiny_model():
    """End-task accuracy, digital MXFP4 vs CIM path (PTQ, no retraining):
    tiny 2-layer MLP classifier on a synthetic task."""
    rng = np.random.default_rng(3)
    d, h, classes, n = 64, 128, 10, 4096
    wproj = rng.standard_normal((d, classes))
    xtr = rng.standard_normal((n, d)).astype(np.float32)
    ytr = (xtr @ wproj).argmax(-1)
    w1 = rng.standard_normal((d, h)).astype(np.float32) * 0.2
    w2 = rng.standard_normal((h, classes)).astype(np.float32) * 0.2
    w1j, w2j = jnp.asarray(w1), jnp.asarray(w2)

    def loss(params, xb, yb):
        a = jnp.maximum(xb @ params[0], 0.0)
        logits = a @ params[1]
        return -jnp.mean(
            jax.nn.log_softmax(logits)[jnp.arange(len(yb)), yb]
        )

    params = [w1j, w2j]
    g = jax.jit(jax.grad(loss))
    xj, yj = jnp.asarray(xtr), jnp.asarray(ytr)
    for _ in range(300):
        grads = g(params, xj, yj)
        params = [p - 0.5 * gg for p, gg in zip(params, grads)]

    def acc_fp32(p1, p2):
        a = np.maximum(np.asarray(xj) @ p1, 0)
        return float(((a @ p2).argmax(-1) == ytr).mean())

    base = acc_fp32(np.asarray(params[0]), np.asarray(params[1]))

    def mx_fwd(x):
        a = jnp.maximum(
            mxlib.mx_dot_bf16(mxlib.quantize(x), mxlib.quantize_w(params[0])),
            0,
        ).astype(jnp.float32)
        return mxlib.mx_dot_bf16(mxlib.quantize(a), mxlib.quantize_w(params[1]))

    acc_mx = float(
        (np.asarray(mx_fwd(xj), np.float32).argmax(-1) == ytr).mean()
    )

    cfg = cimlib.CIMConfig(adc_bits=10, cm_bits=3, two_pass=True)
    w1q, w2q = mxlib.quantize_w(params[0]), mxlib.quantize_w(params[1])
    cal1 = cimlib.calibrate_rowhist([xj[:256]], w1q, cfg)
    a1, _ = cimlib.cim_linear(xj, w1q, cfg, cal1)
    a1 = jnp.maximum(a1, 0)
    cal2 = cimlib.calibrate_rowhist([a1[:256]], w2q, cfg)
    lo, _ = cimlib.cim_linear(a1, w2q, cfg, cal2)
    acc_cim = float((np.asarray(lo).argmax(-1) == ytr).mean())
    drop = (acc_mx - acc_cim) * 100
    return (
        f"fp32 {base:.3f} | mxfp4 {acc_mx:.3f} | cim {acc_cim:.3f} "
        f"(drop {drop:.2f} pp; paper claims <=1pp)"
    )


@bench
def hybrid_backend_tiny_lm():
    """End-to-end hybrid analog/digital transformer (the backend registry
    path): tiny LM, Row-Hist calibrated + converted to resident CIM
    arrays, digital-MXFP4-vs-hybrid logit fidelity and decode smoke."""
    import dataclasses

    from repro import configs as C
    from repro.layers.common import RunCtx, ShardingCtx
    from repro.models import calibrate, lm

    cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
    cim_cfg = cimlib.CIMConfig()
    batches = calibrate.calibration_batches(cfg, n_batches=2, batch=2, seq=16)
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, ctx, batches, cim_cfg=cim_cfg, min_n=32
    )
    dig, _ = lm.forward(
        params, cfg, dataclasses.replace(ctx, quant="mxfp4_digital"),
        batches[0],
    )
    hyb_ctx = dataclasses.replace(ctx, quant="cim", cim=cim_cfg)
    hyb, _ = lm.forward(conv, cfg, hyb_ctx, batches[0])
    d = np.asarray(dig, np.float32)
    h = np.asarray(hyb, np.float32)
    agree = float((d.argmax(-1) == h.argmax(-1)).mean())
    return (
        f"{len(calibs)} analog linears; hybrid-vs-digital logit SQNR "
        f"{_sqnr_db(d, h):.1f} dB, top-1 agree {agree:.2f} "
        f"(paper: <1pp accuracy drop on trained models)"
    )


@bench
def fidelity_sweep():
    """Numerical-fidelity observability sweep (the ``--fidelity`` serving
    pass in batch form): per-layer SQNR, quantizer/ADC health and drift
    verdicts on the tiny LM and tiny ViT across execution variants —
    digital MXFP4 vs float, hybrid CIM vs its digital-matched reference,
    lossless CIM (the exactness gate), and a deliberately mis-calibrated
    hybrid (``adc_fs / 4``) that must trip the drift detector *and*
    degrade SQNR in the same run. Also measures the probe's overhead
    (instrumented eager pass vs the plain serving forward). Writes
    BENCH_fidelity.json."""
    import dataclasses
    import json

    from repro import configs as C
    from repro import obs as obs_lib
    from repro.layers.common import RunCtx, ShardingCtx
    from repro.models import calibrate, lm, vit

    LOSSLESS = cimlib.CIMConfig(adc_bits=None, cm_bits=64, two_pass=False)

    def digest(rep):
        lay = rep["layers"]
        return {
            "output_sqnr_db": rep["sqnr_db"].get("output"),
            "sqnr_db": rep["sqnr_db"],
            "n_drifted": rep["drift"]["n_drifted"],
            "drifted": rep["drift"]["drifted"],
            "max_clip_ratio": max(
                (v.get("clip_ratio", 0.0) for v in lay.values()), default=0.0
            ),
            "max_adc_saturation_ratio": max(
                (v.get("adc_saturation_ratio", 0.0) for v in lay.values()),
                default=0.0,
            ),
            "layers": lay,
        }

    def sweep(cfg, init_fn, forward_fn, batches):
        params, _ = init_fn(jax.random.PRNGKey(0), cfg)
        ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
        cim_cfg = cimlib.CIMConfig()
        conv, calibs = calibrate.convert_model_cim(
            params, cfg, ctx, batches, cim_cfg=cim_cfg, min_n=32,
            forward_fn=forward_fn,
        )
        conv_ll, _ = calibrate.convert_model_cim(
            params, cfg, ctx, batches, cim_cfg=LOSSLESS, min_n=32,
            forward_fn=forward_fn,
        )
        batch = batches[0]

        def one(tree, quant, ref_quant, run_ctx):
            _, rep = obs_lib.run_fidelity_pass(
                params, tree, cfg, run_ctx, batch,
                forward_fn=forward_fn, ref_quant=ref_quant, quant=quant,
            )
            return rep

        hyb_ctx = dataclasses.replace(ctx, quant="cim", cim=cim_cfg)
        out = {"analog_linears": len(calibs), "variants": {}}
        # digital MXFP4 vs bf16 float: total quantization error
        out["variants"]["mxfp4"] = digest(one(params, "mxfp4_digital",
                                              "none", ctx))
        # hybrid CIM vs its digital-matched reference: analog-stack noise
        t0 = time.time()
        rep_cim = one(conv, "cim", "mxfp4_digital", hyb_ctx)
        on_s = time.time() - t0
        out["variants"]["cim"] = digest(rep_cim)
        # lossless CIM: must match digital MXFP4 (the CI exactness gate)
        out["variants"]["cim_lossless"] = digest(one(
            conv_ll, "cim", "mxfp4_digital",
            dataclasses.replace(ctx, quant="cim", cim=LOSSLESS),
        ))
        # shrunken adc_fs: drift verdicts + degraded SQNR, correlated
        out["variants"]["cim_miscal"] = digest(one(
            obs_lib.scale_adc_fs(conv, 0.25), "cim", "mxfp4_digital",
            hyb_ctx,
        ))
        # probe overhead: instrumented eager pass (two forwards + health
        # probes) vs the plain serving forward it rides alongside
        jax.block_until_ready(forward_fn(conv, cfg, hyb_ctx, batch))  # warm
        t0 = time.time()
        jax.block_until_ready(forward_fn(conv, cfg, hyb_ctx, batch))
        off_s = time.time() - t0
        out["overhead"] = {
            "fidelity_off_ms": off_s * 1e3,
            "fidelity_on_ms": on_s * 1e3,
            "ratio": on_s / max(off_s, 1e-9),
        }
        return out

    lm_cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    lm_batches = calibrate.calibration_batches(
        lm_cfg, n_batches=2, batch=2, seq=16
    )
    vit_cfg = C.geometry_tiny_vit(C.VISION_ARCHS["vit-b16"])
    vit_batches = vit.calibration_images(vit_cfg, n_batches=2, batch=1)

    result = {
        "meta": _run_meta(),
        "models": {
            "tiny_lm": sweep(lm_cfg, lm.init_model, lm.forward, lm_batches),
            "tiny_vit": sweep(vit_cfg, vit.init_model, vit.forward,
                              vit_batches),
        },
    }
    lmr = result["models"]["tiny_lm"]["variants"]
    result["gate"] = {
        # CI fidelity gate inputs: lossless hybrid must stay essentially
        # exact and calibrated traffic must never read as drifted
        "lm_lossless_output_sqnr_db": lmr["cim_lossless"]["output_sqnr_db"],
        "lm_cim_n_drifted": lmr["cim"]["n_drifted"],
        "lm_miscal_n_drifted": lmr["cim_miscal"]["n_drifted"],
        "lm_analog_linears": result["models"]["tiny_lm"]["analog_linears"],
    }
    with open("BENCH_fidelity.json", "w") as f:
        json.dump(result, f, indent=1)
    g = result["gate"]
    ov = result["models"]["tiny_lm"]["overhead"]
    return (
        f"lossless {g['lm_lossless_output_sqnr_db']:.0f} dB, hybrid "
        f"{lmr['cim']['output_sqnr_db']:.1f} dB / drift "
        f"{g['lm_cim_n_drifted']}, miscal "
        f"{lmr['cim_miscal']['output_sqnr_db']:.1f} dB / drift "
        f"{g['lm_miscal_n_drifted']}/{g['lm_analog_linears']}; probe "
        f"{ov['ratio']:.0f}x eager -> BENCH_fidelity.json"
    )


@bench
def serving_engine_tiny_lm():
    """Continuous-batching serving engine vs naive static batching: tiny
    full-attention LM, staggered synthetic requests with mixed lengths.
    Writes BENCH_serving.json (tokens/s, simulated p50/p99 latency on the
    twelve-stage FWS pipeline model, slot utilization both ways, host
    TTFT / per-token percentiles, SLO verdict, telemetry overhead)."""
    import json

    from repro import configs as C
    from repro import obs as obs_lib
    from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
    from repro.models import lm
    from repro.serving import Engine, EngineConfig
    from repro.serving import pipeline as pipe
    from repro.serving.scheduler import Request, static_batching_plan

    cfg = C.tiny(C.ARCHS["starcoder2-7b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params = convert_params_mxfp4(params)
    ctx = RunCtx(shd=ShardingCtx(), quant="mxfp4_wonly", dense_attn_max=256)
    ecfg = EngineConfig(lanes=4, num_slots=6, page_len=32, prefill_len=12)
    eng = Engine(params, cfg, ctx, ecfg)

    rng = np.random.default_rng(0)
    n_requests = 12
    specs = []
    for _ in range(n_requests):
        n = int(rng.integers(2, ecfg.prefill_len + 1))
        specs.append((rng.integers(0, cfg.vocab_size, size=n).tolist(),
                      int(rng.integers(2, 12))))

    def warm(engine):
        # warm both jitted steps (prefill + decode) so wall time measures
        # the engine, not XLA compilation
        engine.add_request(specs[0][0], max_new=2)
        engine.run()

    def burst(engine):
        done_before = set(engine.requests)
        engine.obs.reset()
        t0 = time.time()
        for prompt, max_new in specs:
            engine.add_request(prompt, max_new=max_new)
            engine.step()  # staggered: requests arrive while engine runs
        res = engine.run()
        return ({r: v for r, v in res.items() if r not in done_before},
                time.time() - t0)

    # telemetry overhead: the same burst on an identical engine with span
    # tracking + registry updates off (the pre-PR-equivalent baseline).
    # Bursts alternate and each side takes its min over rounds — a single
    # ~50ms burst on a shared box is dominated by scheduler noise.
    eng_off = Engine(params, cfg, ctx, ecfg,
                     obs=obs_lib.Obs(enabled=False))
    warm(eng)
    warm(eng_off)
    walls, walls_off = [], []
    for _ in range(3):
        _, w_off = burst(eng_off)
        walls_off.append(w_off)
        out, w = burst(eng)
        walls.append(w)
    wall, wall_off = min(walls), min(walls_off)
    n_tok = sum(len(v) for v in out.values())

    telemetry = eng.obs.request_summary()
    slo = obs_lib.evaluate_slo(
        eng.obs.finished,
        # generous CI-box targets: catches order-of-magnitude serving
        # regressions, not scheduler jitter on shared runners
        obs_lib.SLOTargets(ttft_p99_s=2.0, token_p99_s=1.0),
    )

    cont = eng.trace_report()
    static_events = static_batching_plan(
        [Request(rid=i, prompt=p, max_new=m)
         for i, (p, m) in enumerate(specs)],
        ecfg.lanes,
        # bill static prefills at the same executed (padded) width the
        # engine's fixed-shape step is billed at, so the continuous-vs-
        # static pipeline comparison stays apples-to-apples
        prefill_len=ecfg.prefill_len,
    )
    stat = pipe.simulate_trace(static_events, cfg.d_model, ecfg.lanes)

    def summarize(rep, slot_util):
        lat = np.asarray(sorted(rep.request_latency.values()))
        return {
            "sim_tokens_per_s": rep.tokens_per_s,
            "sim_p50_latency_s": float(np.percentile(lat, 50)),
            "sim_p99_latency_s": float(np.percentile(lat, 99)),
            "sim_makespan_s": rep.pipeline.makespan,
            "slot_utilization": slot_util,
            "stage_utilization": rep.pipeline.stage_utilization,
        }

    result = {
        "meta": _run_meta(),
        "arch": cfg.name,
        "backend": "mxfp4",
        "lanes": ecfg.lanes,
        "num_slots": ecfg.num_slots,
        "page_len": ecfg.page_len,
        "n_requests": n_requests,
        "tokens_generated": n_tok,
        "wall_s": wall,
        "tokens_per_s_wall": n_tok / wall,
        "continuous": summarize(cont, eng.slot_utilization),
        "static": summarize(stat, stat.lane_utilization),
        "telemetry": telemetry,
        "slo": slo,
        "obs_overhead": {
            "wall_enabled_s": wall,
            "wall_disabled_s": wall_off,
            "ratio": wall / max(wall_off, 1e-9),
        },
    }
    result["sim_speedup_vs_static"] = (
        result["static"]["sim_makespan_s"]
        / result["continuous"]["sim_makespan_s"]
    )
    with open("BENCH_serving.json", "w") as f:
        json.dump(result, f, indent=2)
    ttft = telemetry["ttft_s"] or {}
    return (
        f"{n_tok} tok, {n_tok / wall:.0f} tok/s wall; sim speedup vs "
        f"static {result['sim_speedup_vs_static']:.2f}x, slot util "
        f"{eng.slot_utilization:.2f} vs {stat.lane_utilization:.2f}; "
        f"ttft p50 {ttft.get('p50', 0) * 1e3:.1f}ms, slo "
        f"{'pass' if slo['pass'] else 'FAIL'}, obs overhead "
        f"{result['obs_overhead']['ratio']:.2f}x -> BENCH_serving.json"
    )


@bench
def serving_load():
    """Trace-driven load harness through the real engine: Poisson and
    deterministic scripted-burst arrivals over a shared-system-prompt
    workload, chunked prefill + prefix cache on (benchmarks/load.py
    scenarios). Merges a "load" key into BENCH_serving.json — per
    arrival process p50/p99 TTFT + per-token latency vs SLO, prefix-hit
    rate and eviction counts, plus the prefix-cache on/off comparison on
    the scripted trace (token-identical outputs asserted, mean TTFT and
    prefill-step counts both ways)."""
    import json
    import os

    import load as load_bench

    from repro import configs as C
    from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
    from repro.models import lm

    cfg = C.tiny(C.ARCHS["starcoder2-7b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params = convert_params_mxfp4(params)
    ctx = RunCtx(shd=ShardingCtx(), quant="mxfp4_wonly", dense_attn_max=256)

    traces = load_bench.scenario_traces(cfg.vocab_size, n=16, rate_rps=200.0)
    mk_on = load_bench.engine_factory(params, cfg, ctx, prefix_cache=True)
    mk_off = load_bench.engine_factory(params, cfg, ctx, prefix_cache=False)

    load = {"engine": dict(load_bench.ENGINE),
            "workload": dict(load_bench.WORKLOAD),
            "slo_targets": load_bench.TARGETS.asdict(),
            "arrivals": {}}
    outs_on = {}
    for name, trace in traces.items():
        rep, outs_on[name] = load_bench.run_scenario(mk_on, trace)
        load["arrivals"][name] = rep

    # prefix-cache off on the scripted (reproducible-arrival) trace: the
    # acceptance invariant — token-identical outputs with a nonzero hit
    # rate and lower mean TTFT / fewer prefill steps when the cache is on
    rep_off, outs_off = load_bench.run_scenario(mk_off, traces["scripted"])
    assert outs_off == outs_on["scripted"], (
        "prefix cache changed generated tokens"
    )
    rep_on = load["arrivals"]["scripted"]
    assert rep_on["prefix"].get("hits", 0) > 0, "no prefix hits on a "\
        "shared-system-prompt trace"
    load["prefix_onoff_scripted"] = {
        "outputs_token_identical": True,
        "hit_rate_on": rep_on["prefix"]["hit_rate"],
        "ttft_mean_s_on": rep_on["ttft_s"]["mean"],
        "ttft_mean_s_off": rep_off["ttft_s"]["mean"],
        "prefill_steps_on": rep_on["steps"]["prefill"],
        "prefill_steps_off": rep_off["steps"]["prefill"],
    }

    # merge into the artifact serving_engine_tiny_lm writes fresh
    doc = {}
    if os.path.exists("BENCH_serving.json"):
        with open("BENCH_serving.json") as f:
            doc = json.load(f)
    doc["load"] = load
    with open("BENCH_serving.json", "w") as f:
        json.dump(doc, f, indent=2, default=str)

    po = load["arrivals"]["poisson"]
    oo = load["prefix_onoff_scripted"]
    return (
        f"poisson ttft p99 {po['ttft_s']['p99'] * 1e3:.1f}ms "
        f"(slo {'pass' if po['slo']['pass'] else 'FAIL'}), scripted hit "
        f"rate {oo['hit_rate_on']:.2f}, prefill steps "
        f"{oo['prefill_steps_on']} vs {oo['prefill_steps_off']} off, "
        f"mean ttft {oo['ttft_mean_s_on'] * 1e3:.1f} vs "
        f"{oo['ttft_mean_s_off'] * 1e3:.1f}ms -> BENCH_serving.json[load]"
    )


@bench
def vit_fws_pipeline():
    """Vision subsystem: executable ViT models on the hybrid CIM stack +
    image-stream FWS serving. Writes BENCH_vit.json — per-backend forward
    latency on the tiny ViT, float<->cim top-1 agreement, and the paper's
    headline Table 7 rows reproduced from *measured* engine stage traffic
    (vit-b16 single-chip, vit-l32 dual-chip 12+12) plus traffic-shaped
    streams (vit-b32, bert-base)."""
    import dataclasses
    import json

    from repro import configs as C
    from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
    from repro.models import calibrate, vit
    from repro.serving.vision import VisionEngine, synthetic_stream_report

    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)

    # ---- per-backend forward latency + fidelity on the tiny ViT
    cfg = C.tiny_vit(C.VISION_ARCHS["vit-b16"])
    params, _ = vit.init_model(jax.random.PRNGKey(0), cfg)
    batches = vit.calibration_images(cfg, n_batches=2, batch=2)
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, ctx, batches, min_n=32, forward_fn=vit.forward,
    )
    variants = {
        "float": (params, ctx),
        "mxfp4": (convert_params_mxfp4(params, min_n=32),
                  dataclasses.replace(ctx, quant="mxfp4_wonly")),
        "cim": (conv, dataclasses.replace(ctx, quant="cim",
                                          cim=cimlib.CIMConfig())),
    }
    images = vit.calibration_images(cfg, n_batches=1, batch=2, seed=9)[0]
    latency_us, logits = {}, {}
    for name, (p, c) in variants.items():
        fwd = jax.jit(lambda pp, img, c=c: vit.forward(
            pp, cfg, c, {"images": img})[0])
        out = fwd(p, images["images"]).block_until_ready()  # compile
        t0 = time.time()
        for _ in range(5):
            out = fwd(p, images["images"]).block_until_ready()
        latency_us[name] = (time.time() - t0) / 5 * 1e6
        logits[name] = np.asarray(out, np.float32)
    agree = float(
        (logits["float"].argmax(-1) == logits["cim"].argmax(-1)).mean()
    )
    cim_sqnr = _sqnr_db(logits["float"], logits["cim"])

    # ---- Table 7 rows from measured stage traffic (geometry-true width-
    # tiny engines for the two headline rows; traffic-shaped for the rest)
    rows = {}
    for wname, n_frames in (("vit-b16", 3), ("vit-l32", 3)):
        gcfg = C.geometry_tiny_vit(C.VISION_ARCHS[wname])
        gp, _ = vit.init_model(jax.random.PRNGKey(0), gcfg)
        eng = VisionEngine(gp, gcfg, ctx)
        frames = jax.random.normal(
            jax.random.PRNGKey(1),
            (n_frames, gcfg.image_size, gcfg.image_size, 3),
        )
        eng.stream(frames)
        rep = eng.fws_report(workload=wname)
        rows[wname] = {
            "measured": True, "chips": rep.chips, "n_tokens": rep.n_tokens,
            "fps": rep.fps, "paper_fps": rep.paper_fps,
            "fps_error": rep.fps_error,
            "frame_latency_us": rep.frame_latency_s * 1e6,
        }
    for wname in ("vit-b32", "bert-base"):
        w = S.WORKLOADS[wname]
        rep = synthetic_stream_report(
            w.seq, w.d, chips=w.chips,
            paper_fps=S.PAPER_TABLE7[wname][1],
        )
        rows[wname] = {
            "measured": False, "chips": rep.chips, "n_tokens": rep.n_tokens,
            "fps": rep.fps, "paper_fps": rep.paper_fps,
            "fps_error": rep.fps_error,
            "frame_latency_us": rep.frame_latency_s * 1e6,
        }

    result = {
        "meta": _run_meta(),
        "tiny_forward_latency_us": latency_us,
        "float_cim_top1_agreement": agree,
        "float_cim_logit_sqnr_db": cim_sqnr,
        "n_analog_linears": len(calibs),
        "table7": rows,
    }
    with open("BENCH_vit.json", "w") as f:
        json.dump(result, f, indent=2)
    worst = max(r["fps_error"] for r in rows.values())
    return (
        f"fwd us float/mxfp4/cim {latency_us['float']:.0f}/"
        f"{latency_us['mxfp4']:.0f}/{latency_us['cim']:.0f}; "
        f"float<->cim agree {agree:.2f}; Table7 "
        + " ".join(f"{k}:{v['fps']:.0f}fps({100 * v['fps_error']:.1f}%)"
                   for k, v in rows.items())
        + f"; worst err {100 * worst:.1f}% -> BENCH_vit.json"
    )


@bench
def backend_latency():
    """Fused quantized hot path: per-backend forward/decode latency on a
    block-aligned tiny LM -> BENCH_backends.json.

    Measures (i) the tiny forward under float / mxfp4 / cim, (ii) decode
    step latency vs cache length per backend — for cim both with the
    quantized-resident KV pool and against the requant-per-step reference
    (legacy cache) — (iii) the per-token KV-quantization primitive
    itself, where the resident path is O(1) in cache length and the
    reference is O(cache_len), and (iv) the paged serving decode over a
    lanes x cache_len grid: the fused head-interleaved pool (in-place
    ragged paged decode via RunCtx.paged_rows) against the legacy
    gather -> decode -> scatter bracketing, plus the pool-I/O component
    alone — the legacy bracket copies O(lanes * cache_len) per step and
    grows linearly, the fused row write is O(lanes) and stays flat. Each
    shape also logs the chunk width / DMA ring depth the Pallas kernel
    picks for it (pick_bk / pick_buffers).

    Methodology notes: the model keeps every quantized dim 32-aligned
    (the paper's head dims are >= 64; a 16-wide smoke head pads every
    SDPA block to 32, which benchmarks the pad, not the datapath).
    Timings interleave the variants round-robin and take the per-variant
    minimum — wall time on shared CI boxes drifts by integer factors, and
    round-robin + min recovers comparable uncontended latencies.
    """
    import dataclasses
    import json

    from repro import configs as C
    from repro.layers import attention as attn_mod
    from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
    from repro.models import calibrate, lm

    base = C.tiny(C.ARCHS["starcoder2-7b"])
    cfg = dataclasses.replace(base, n_heads=2, n_kv_heads=2, head_dim=32)
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
    cim_cfg = cimlib.CIMConfig()
    batches = calibrate.calibration_batches(cfg, n_batches=2, batch=2, seq=16)
    conv, _ = calibrate.convert_model_cim(
        params, cfg, ctx, batches, cim_cfg=cim_cfg, min_n=32
    )
    variants = {
        "float": (params, ctx),
        "mxfp4": (convert_params_mxfp4(params),
                  dataclasses.replace(ctx, quant="mxfp4_wonly")),
        "cim": (conv, dataclasses.replace(ctx, quant="cim", cim=cim_cfg)),
    }

    def interleaved_min(fns, reps=50):
        best = {k: float("inf") for k in fns}
        for k, f in fns.items():  # warm/compile
            f()
        order = list(fns)
        for r in range(reps):
            for k in order:
                t0 = time.perf_counter()
                fns[k]()
                best[k] = min(best[k], time.perf_counter() - t0)
            order = order[1:] + order[:1]  # rotate: cancel ordering bias
        return {k: v * 1e6 for k, v in best.items()}

    # ---- tiny forward (seq 32 — the repo's tiny smoke geometry; the
    # digital-SDPA P-quantize scales with S^2, so longer sequences mostly
    # benchmark the SDPA simulation rather than the linear hot path)
    batch = {"ids": jax.random.randint(jax.random.PRNGKey(9), (2, 32), 0,
                                       cfg.vocab_size)}
    fwd_fns = {}
    for name, (p, c) in variants.items():
        f = jax.jit(lambda pp, b, c=c: lm.forward(pp, cfg, c, b)[0])
        fwd_fns[name] = (
            lambda f=f, p=p: f(p, batch).block_until_ready()
        )
    forward_us = interleaved_min(fwd_fns)

    # ---- decode latency vs cache length (per-lane pos, jitted step)
    cache_lens = (64, 256, 1024)
    decode_us: dict = {}
    for W in cache_lens:
        fns = {}
        for name, (p, c) in variants.items():
            for label, mx_pool in (
                (name, c.hybrid_digital_sdpa),
                (f"{name}_requant", False),
            ):
                if label.endswith("_requant") and not c.hybrid_digital_sdpa:
                    continue  # requant reference only differs for cim
                caches = lm.init_cache(cfg, 2, W, mx_digital=mx_pool)
                _, caches = lm.forward(
                    p, cfg, c, {"ids": batch["ids"][:, :16]}, caches=caches
                )
                step = jax.jit(
                    lambda pp, cc, i, pos, c=c: lm.decode_step(
                        pp, cfg, c, i, pos, cc
                    )
                )
                ids = jnp.ones((2, 1), jnp.int32)
                pos = jnp.int32(W - 1)
                fns[label] = (
                    lambda step=step, p=p, caches=caches, ids=ids, pos=pos:
                    step(p, caches, ids, pos)[0].block_until_ready()
                )
        decode_us[W] = interleaved_min(fns)

    # ---- per-token KV quantization primitive: resident O(1) vs
    # requant-per-step O(cache_len)
    kv_quant_us: dict = {}
    b, h, d = 2, cfg.n_kv_heads, cfg.hd
    for W in cache_lens:
        key = jax.random.PRNGKey(W)
        ck = jax.random.normal(key, (b, W, h, d), jnp.bfloat16)
        cv = jax.random.normal(key, (b, W, h, d), jnp.bfloat16)
        qc = attn_mod.quant_cache_init(b, W, h, d)
        lanes = jnp.arange(b)
        slot = jnp.full((b,), W - 1, jnp.int32)
        jreq = jax.jit(lambda ck, cv: (
            mxlib.fake_quant(ck.astype(jnp.float32)),
            mxlib.fake_quant_axis(cv.astype(jnp.float32), 1),
        ))
        jres = jax.jit(attn_mod._quant_cache_step)
        kv_quant_us[W] = interleaved_min({
            "resident": lambda: jax.tree.map(
                lambda x: x.block_until_ready(),
                jres(qc, ck, cv, lanes, slot),
            ),
            "requant": lambda: jax.tree.map(
                lambda x: x.block_until_ready(), jreq(ck, cv)
            ),
        })

    # ---- paged serving decode: fused in-place pool vs gather/scatter
    # (quantized-resident pool — the mx mirrors make the legacy bracket's
    # per-step copy volume the worst case)
    from repro.kernels.paged_attention import ops as paged_ops
    from repro.serving import kvcache as kv_mod

    dctx = dataclasses.replace(ctx, quant="mxfp4_digital")
    dparams = variants["mxfp4"][0]
    paged_decode_us: dict = {}
    paged_pool_io_us: dict = {}
    paged_knobs: dict = {}
    for lanes in (2, 4):
        for W in (64, 256, 512, 1024):
            shape_key = f"{lanes}x{W}"
            bk = paged_ops.pick_bk(W)
            paged_knobs[shape_key] = {
                "bk": bk, "buffers": paged_ops.pick_buffers(W, bk)
            }
            rows = jnp.arange(lanes, dtype=jnp.int32)
            ids = jnp.ones((lanes, 1), jnp.int32)
            pos = jnp.full((lanes,), W - 1, jnp.int32)
            kv_leg = kv_mod.PagedKVCache(cfg, lanes, lanes, W,
                                         mx_digital=True)
            kv_fus = kv_mod.PagedKVCache(cfg, lanes, lanes, W,
                                         mx_digital=True, layout="fused")

            def leg_step(pp, pool, rows, ids, pos, specs=kv_leg.specs):
                caches = kv_mod.gather_rows(pool, specs, rows)
                lg, caches = lm.decode_step(pp, cfg, dctx, ids, pos, caches)
                return lg, kv_mod.scatter_rows(pool, specs, rows, caches)

            def fus_step(pp, pool, rows, ids, pos):
                c = dataclasses.replace(dctx, paged_rows=rows)
                return lm.decode_step(pp, cfg, c, ids, pos, pool)

            jleg, jfus = jax.jit(leg_step), jax.jit(fus_step)
            paged_decode_us[shape_key] = interleaved_min({
                "gather": lambda jleg=jleg, pool=kv_leg.pool:
                    jleg(dparams, pool, rows, ids, pos)[0]
                    .block_until_ready(),
                "fused": lambda jfus=jfus, pool=kv_fus.pool:
                    jfus(dparams, pool, rows, ids, pos)[0]
                    .block_until_ready(),
            }, reps=20)

            # pool-I/O component alone: the legacy gather/scatter
            # roundtrip vs the fused per-token row write
            jio_leg = jax.jit(
                lambda pool, rows, specs=kv_leg.specs: kv_mod.scatter_rows(
                    pool, specs, rows,
                    kv_mod.gather_rows(pool, specs, rows),
                )
            )
            newrow = jnp.ones(
                (lanes, 2 * cfg.n_kv_heads, cfg.hd), jnp.bfloat16
            )

            def row_write(pool, rows, nr, specs=kv_fus.specs, W=W):
                # scanned segments carry a leading layers axis; index the
                # batch/cache_seq axes from the spec like scatter_rows
                out = []
                for seg, spec in zip(pool, specs):
                    ax = spec["kv"].index("batch")
                    idx = (slice(None),) * ax + (rows, W - 1)
                    out.append({**seg, "kv": seg["kv"].at[idx].set(nr)})
                return out

            jio_fus = jax.jit(row_write)
            paged_pool_io_us[shape_key] = interleaved_min({
                "gather_scatter": lambda pool=kv_leg.pool: jax.tree.map(
                    lambda x: x.block_until_ready(),
                    jio_leg(pool, rows),
                ),
                "row_write": lambda pool=kv_fus.pool: jax.tree.map(
                    lambda x: x.block_until_ready(),
                    jio_fus(pool, rows, newrow),
                ),
            }, reps=20)

    io_growth_leg = (
        paged_pool_io_us["4x1024"]["gather_scatter"]
        / max(paged_pool_io_us["4x64"]["gather_scatter"], 1e-9)
    )
    io_growth_fus = (
        paged_pool_io_us["4x1024"]["row_write"]
        / max(paged_pool_io_us["4x64"]["row_write"], 1e-9)
    )

    # ---- ragged-kernel scaling at fixed occupancy: lanes hold `occ`
    # valid tokens while the allocated page grows. The streaming kernel
    # runs ceil(occ / bk) chunks per lane — page-size independent — while
    # the dense gather path attends the whole masked page, O(page_len).
    # Interpret-mode wall time tracks executed chunk count, so the
    # *growth* of each curve across page sizes is meaningful even though
    # absolute interpret latencies are not comparable to compiled jnp.
    occ, pl_lanes = 64, 4
    h_kv, dh = cfg.n_kv_heads, cfg.hd
    paged_fixed_occ_us: dict = {}
    for W in (64, 256, 512, 1024):
        key = jax.random.PRNGKey(W)
        pages = jax.random.normal(
            key, (pl_lanes, W, 2 * h_kv, dh)
        ).astype(jnp.bfloat16)
        qh = jax.random.normal(key, (pl_lanes, h_kv, 4, dh)).astype(
            jnp.bfloat16
        )
        rows = jnp.arange(pl_lanes, dtype=jnp.int32)
        lens = jnp.full((pl_lanes,), occ, jnp.int32)
        sc = float(dh) ** -0.5
        kern = lambda pages=pages, qh=qh, rows=rows, lens=lens, sc=sc: (
            paged_ops.ragged_paged_decode(
                qh, rows, lens, kv=pages, scale=sc, use_pallas=True,
                interpret=True, bk=32, buffers=2,
            ).block_until_ready()
        )
        ref = jax.jit(
            lambda pages, qh, rows, lens, sc=sc:
            paged_ops.ragged_paged_decode(
                qh, rows, lens, kv=pages, scale=sc, use_pallas=False
            )
        )
        refc = lambda ref=ref, pages=pages, qh=qh, rows=rows, lens=lens: (
            ref(pages, qh, rows, lens).block_until_ready()
        )
        paged_fixed_occ_us[str(W)] = interleaved_min(
            {"kernel_interpret": kern, "gather_ref": refc}, reps=5
        )
    occ_growth = {
        name: (paged_fixed_occ_us["1024"][name]
               / max(paged_fixed_occ_us["64"][name], 1e-9))
        for name in ("kernel_interpret", "gather_ref")
    }

    ratios = {
        "mxfp4_vs_float": forward_us["mxfp4"] / forward_us["float"],
        "cim_vs_float": forward_us["cim"] / forward_us["float"],
    }
    res_flat = (
        kv_quant_us[cache_lens[-1]]["resident"]
        / max(kv_quant_us[cache_lens[0]]["resident"], 1e-9)
    )
    req_growth = (
        kv_quant_us[cache_lens[-1]]["requant"]
        / max(kv_quant_us[cache_lens[0]]["requant"], 1e-9)
    )
    result = {
        "meta": _run_meta(),
        "arch": cfg.name,
        "note": "tiny LM, 32-aligned head_dim; interleaved min-of-reps",
        "tiny_forward_latency_us": forward_us,
        "forward_ratio": ratios,
        "decode_latency_us": {str(w): v for w, v in decode_us.items()},
        "kv_quant_step_us": {str(w): v for w, v in kv_quant_us.items()},
        "kv_quant_resident_growth_64_to_1024": res_flat,
        "kv_quant_requant_growth_64_to_1024": req_growth,
        "paged_decode_us": paged_decode_us,
        "paged_pool_io_us": paged_pool_io_us,
        "paged_kernel_knobs": paged_knobs,
        "paged_pool_io_growth_64_to_1024": {
            "gather_scatter": io_growth_leg, "row_write": io_growth_fus,
        },
        "paged_fixed_occupancy_us": paged_fixed_occ_us,
        "paged_fixed_occupancy_growth_64_to_1024": occ_growth,
    }
    with open("BENCH_backends.json", "w") as f:
        json.dump(result, f, indent=2)
    return (
        f"fwd us f/m/c {forward_us['float']:.0f}/{forward_us['mxfp4']:.0f}/"
        f"{forward_us['cim']:.0f} (mxfp4 {ratios['mxfp4_vs_float']:.2f}x, "
        f"cim {ratios['cim_vs_float']:.2f}x); KV-quant growth 64->1024: "
        f"resident {res_flat:.2f}x vs requant {req_growth:.2f}x; paged "
        f"pool I/O growth 64->1024: gather/scatter {io_growth_leg:.1f}x "
        f"vs fused row write {io_growth_fus:.1f}x; fixed-occupancy decode "
        f"growth 64->1024: ragged kernel "
        f"{occ_growth['kernel_interpret']:.2f}x vs dense gather "
        f"{occ_growth['gather_ref']:.2f}x -> BENCH_backends.json"
    )


@bench
def pipeline_multidevice():
    """Real shard_map stage-parallel pipeline vs the discrete-event FWS
    model. Writes BENCH_pipeline.json.

    For the tiny LM and the geometry-true tiny ViT the bench (i) measures
    the trunk step wall at two microbatch counts M1/M2 and two-point-fits
    the GPipe schedule — ``t_mb = (w2 - w1) / (M2 - M1)`` is the measured
    steady-state per-microbatch drain spacing, ``fill = w1 - M1 * t_mb``
    the pipeline-fill cost, ``bubble = fill / w2`` the fill bubble at M2 —
    then (ii) cross-validates ``serving.pipeline.simulate`` against the
    measured schedule two ways:

    - *calibrated*: per-stage service time calibrated from the M1 run
      (``w1 / (M1 + S - 1)``) drives ``simulate(stage_time_fn=...)`` to
      predict the M2 step wall — a genuine extrapolation across
      microbatch counts; the agreement gap is the headline number (the
      DES schedule is exact, so the gap is dispatch jitter — percent-level
      on a quiet box).
    - *isolated*: the isolated measured per-stage walls drive the DES
      directly. On real multi-device hardware this is the honest absolute
      prediction; under ``--xla_force_host_platform_device_count`` the
      fake devices share one CPU's cores, so isolated walls (all cores)
      undershoot the contended lockstep step and this gap mostly measures
      host core contention — reported with that caveat, not gated.

    The HLO transfer guard (collective kinds + wire bytes vs resident
    trunk bytes) rides along.

    Stage count adapts to the visible device count (1/2/4) — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU for the
    real multi-device shape; with 2x the devices a 2-replica run checks
    data-parallel throughput scaling.
    """
    import dataclasses
    import json

    from repro import configs as C
    from repro.layers.common import RunCtx, ShardingCtx
    from repro.models import lm, vit
    from repro.serving import pipeline as pipe

    n_dev = jax.device_count()
    stages = max(s for s in (1, 2, 4) if s <= n_dev)
    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
    M1, M2, REPS = 2, 4, 3

    lm_cfg = dataclasses.replace(
        C.tiny(C.ARCHS["starcoder2-7b"]), n_layers=4
    )
    lm_params, _ = lm.init_model(jax.random.PRNGKey(0), lm_cfg)
    vit_cfg = C.geometry_tiny_vit(C.VISION_ARCHS["vit-b16"])
    vit_params, _ = vit.init_model(jax.random.PRNGKey(0), vit_cfg)

    def lm_batch(n):
        # seq 128: long enough that stage compute dwarfs per-step dispatch
        # (at seq 32 the tiny trunk is dispatch-bound and walls go flat)
        return {"ids": jax.random.randint(
            jax.random.PRNGKey(7), (n, 128), 0, lm_cfg.vocab_size)}

    def vit_batch(n):
        return {"images": jax.random.normal(
            jax.random.PRNGKey(7),
            (n, vit_cfg.image_size, vit_cfg.image_size, 3))}

    def study(build, batch_of, d_model, n_tok):
        r2 = build(M2, 1)
        batch2 = batch_of(r2.capacity)
        w2 = r2.measure_step_wall(batch2, reps=REPS)
        w1 = build(M1, 1).measure_step_wall(batch_of(M1), reps=REPS)
        t_mb = (w2 - w1) / (M2 - M1)
        fill = max(0.0, w1 - M1 * t_mb)
        stage_walls = r2.measure_stage_walls(batch2, reps=REPS)
        jobs = [pipe.Job(0.0, n_tok) for _ in range(M2)]
        # calibrated DES: service time fitted on the M1 run, makespan
        # predicted for M2 — the schedule extrapolation the model is for
        t_service = w1 / (M1 + stages - 1)
        sim_cal = pipe.simulate(
            jobs, d_model, n_stages=stages,
            stage_time_fn=lambda n, d, k: t_service,
        )
        gap_cal = abs(w2 - sim_cal.makespan) / sim_cal.makespan
        # isolated DES: contention-free per-stage walls (see docstring)
        sim_iso = pipe.simulate(
            jobs, d_model, n_stages=stages,
            stage_time_fn=lambda n, d, k: stage_walls[k],
        )
        sim_t_mb = 1.0 / sim_iso.steady_state_fps
        gap_iso = abs(w2 - sim_iso.makespan) / sim_iso.makespan
        gap_steady = abs(t_mb - sim_t_mb) / sim_t_mb
        coll = r2.collectives(batch2)
        _, full_wall = r2.timed_forward(batch2)
        out = {
            "stages": stages,
            "microbatches": [M1, M2],
            "mb_size": 1,
            "step_wall_s": {"M1": w1, "M2": w2},
            "full_forward_wall_s": full_wall,
            "two_point_fit": {
                "t_mb_s": t_mb,
                "fill_s": fill,
                "bubble_fraction": fill / w2 if w2 else 0.0,
            },
            "steady_items_per_s": 1.0 / t_mb if t_mb > 0 else None,
            "stage_walls_s": stage_walls,
            "simulated_calibrated": {
                "service_time_s": t_service,
                "makespan_s": sim_cal.makespan,
                "fill_latency_s": sim_cal.fill_latency_s,
                "bubble_fraction": sim_cal.bubble_fraction,
            },
            "simulated_isolated_walls": {
                "makespan_s": sim_iso.makespan,
                "t_mb_s": sim_t_mb,
                "fill_latency_s": sim_iso.fill_latency_s,
                "bubble_fraction": sim_iso.bubble_fraction,
                "note": "isolated walls use all host cores; under forced "
                        "host devices the lockstep step contends for them, "
                        "so this gap mostly measures core contention",
            },
            "agreement_gap": {
                "makespan_calibrated": gap_cal,
                "makespan_isolated_walls": gap_iso,
                "steady_spacing_isolated": gap_steady,
            },
            "transfer_guard": {
                "collective_kinds": sorted(coll.by_kind),
                "wire_bytes": coll.wire_bytes,
                "trunk_bytes": r2.trunk_bytes,
            },
        }
        if n_dev >= 2 * stages:
            rr = build(M2, 2)
            wr = rr.measure_step_wall(batch_of(rr.capacity), reps=REPS)
            out["replica_scaling"] = {
                "replicas": 2,
                "step_wall_s": wr,
                # same per-replica work in one step: ideal scaling = 1.0x
                # wall, 2.0x rows; report rows/s ratio vs the R=1 run
                "throughput_ratio_vs_1": (rr.capacity / wr) / (M2 / w2),
            }
        return out

    def build_lm(m, r):
        from repro.distributed import pipeline_exec as pex

        return pex.build_lm_pipeline(
            lm_params, lm_cfg, ctx, stages=stages, replicas=r,
            microbatches=m, mb_size=1,
        )

    def build_vit(m, r):
        from repro.distributed import pipeline_exec as pex

        return pex.build_vit_pipeline(
            vit_params, vit_cfg, ctx, stages=stages, replicas=r,
            microbatches=m, mb_size=1,
        )

    result = {
        "meta": _run_meta(),
        "stages": stages,
        "models": {
            "tiny_lm": study(build_lm, lm_batch, lm_cfg.d_model, 32),
            "geometry_tiny_vit": study(build_vit, vit_batch,
                                       vit_cfg.d_model, vit_cfg.seq_len),
        },
    }
    worst = max(
        m["agreement_gap"]["makespan_calibrated"]
        for m in result["models"].values()
    )
    result["worst_calibrated_makespan_gap"] = worst
    with open("BENCH_pipeline.json", "w") as f:
        json.dump(result, f, indent=1)
    lmres = result["models"]["tiny_lm"]
    vitres = result["models"]["geometry_tiny_vit"]
    return (
        f"S={stages} lm: t_mb {lmres['two_point_fit']['t_mb_s'] * 1e3:.1f}ms"
        f" cal-gap {100 * lmres['agreement_gap']['makespan_calibrated']:.1f}"
        f"%; vit: t_mb {vitres['two_point_fit']['t_mb_s'] * 1e3:.1f}ms "
        f"cal-gap "
        f"{100 * vitres['agreement_gap']['makespan_calibrated']:.1f}% "
        f"(isolated-walls gap "
        f"{100 * vitres['agreement_gap']['makespan_isolated_walls']:.0f}% — "
        f"host core contention); collectives "
        f"{lmres['transfer_guard']['collective_kinds']} wire "
        f"{lmres['transfer_guard']['wire_bytes']:.0f}B vs trunk "
        f"{lmres['transfer_guard']['trunk_bytes']}B -> BENCH_pipeline.json"
    )


@bench
def fig12_seqlen_sweep():
    rows = perf.fig12_sweep()
    peak = max(rows, key=lambda r: r["tops"])
    return (
        f"peak {peak['tops']:.0f} TOPS at N={peak['N']} "
        f"(paper: 1515 at N=256); "
        + " ".join(f"N={r['N']}:{r['tops']:.0f}" for r in rows)
    )


@bench
def table7_models():
    t7 = perf.table7()
    out = []
    for name, (pw, pfps, ptops) in S.PAPER_TABLE7.items():
        m = t7[name]
        out.append(f"{name}: {m['fps']:.0f} fps (paper {pfps})")
    return " | ".join(out)


@bench
def table8_gpu_comparison():
    large = perf.table4()["large"]
    return (
        f"MXFormer-L {large['tops_w']:.1f} TOPS/W vs B200(ViT) 4.5, "
        f"{large['tops_mm2']:.2f} TOPS/mm2 vs B200(ViT) 1.13"
    )


@bench
def table9_sota_comparison():
    w = S.WORKLOADS["deit-b16"]
    fps = perf.fps(w)
    paper_fps = S.PAPER_TABLE9["deit-b16"]
    ibm_tops_mm2 = 0.22
    ours = perf.table4()["base"]["tops_mm2"]
    return (
        f"DeiT-B/16 {fps:.0f} img/s (paper {paper_fps:,}); "
        f"TOPS/mm2 vs IBM FWS: {ours / ibm_tops_mm2:.1f}x (paper ~20.9x)"
    )


@bench
def kernel_mxfp4_matmul_microbench():
    from repro.kernels.mxfp4_matmul import ops as mm_ops, ref as mm_ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 256), jnp.bfloat16)
    w = jax.random.normal(key, (256, 128), jnp.float32)
    wq = mxlib.quantize_w(w)
    codes = mxlib.pack_codes(wq.codes.T).T
    exps = mxlib.exps_to_biased(wq.exps)
    out = mm_ops.mxfp4_matmul(x, codes, exps, interpret=True)
    ref = mm_ref.mxfp4_matmul_ref(x, codes, exps)
    err = float(
        jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    )
    return f"interpret-mode max err {err:.3e}; packed density 4.25 b/param"


@bench
def digital_attention_fidelity():
    key = jax.random.PRNGKey(1)
    q, k, v = (
        jax.random.normal(jax.random.fold_in(key, i), (2, 64, 64), jnp.float32)
        for i in range(3)
    )
    out = digital.mx_attention(q, k, v, causal=True)
    ref = digital.attention_ref(q, k, v, causal=True)
    return f"MXFP4 attention SQNR {_sqnr_db(ref, out):.1f} dB (bf16 accum)"


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose name contains this "
                         "substring (e.g. --only serving)")
    args = ap.parse_args(argv)
    for fn in (
        table1_io_penalty,
        table2_nvm_density,
        table3_macro,
        table4_systems,
        table5_breakdown,
        fig5_exponent_strategies,
        fig6_saturation,
        fig7_adc_sweep,
        table6_accuracy_tiny_model,
        hybrid_backend_tiny_lm,
        fidelity_sweep,
        serving_engine_tiny_lm,
        serving_load,
        vit_fws_pipeline,
        backend_latency,
        pipeline_multidevice,
        fig12_seqlen_sweep,
        table7_models,
        table8_gpu_comparison,
        table9_sota_comparison,
        kernel_mxfp4_matmul_microbench,
        digital_attention_fidelity,
    ):
        if args.only and args.only not in fn.__name__:
            continue
        fn()
    print("name,us_per_call,derived")
    for name, us, derived in ROWS:
        print(f'{name},{us:.0f},"{derived}"')


if __name__ == "__main__":
    main()
