"""Numerical-fidelity observability for the hybrid CIM stack.

Where the serving telemetry (``repro.obs.tracing``) watches *requests*,
this module watches *numerics*: per-layer MXFP4 quantizer health (clip /
underflow / block-exponent occupancy), ADC code utilization and
saturation, per-layer SQNR against a reference forward, and a
calibration-drift detector that compares live Row-Hist statistics
against the stored :class:`~repro.core.cim.LayerCalib`.

The :class:`FidelityProbe` attaches to ``RunCtx.fidelity`` and is called
by ``layers.common.linear_apply`` with the same scoped param-tree paths
Row-Hist calibration uses, so every metric is keyed by the layer it
describes. Probes run *eagerly* with layers unrolled (the calibration-
capture regime); the compiled hot path never sees any of this — with
``fidelity=None`` (the default) the forward is bitwise unchanged.

Module-load discipline: numpy-only (no jax import at module load) —
device work is imported lazily inside the probe methods. Home of
``sqnr_db`` — previously ``repro.core.metrics``, which now re-exports
from here for compatibility.
"""

from __future__ import annotations

import math

import numpy as np

from repro.obs.log import get_logger, kv
from repro.obs.registry import EXP_BUCKETS, RATIO_BUCKETS

# backends whose forward quantizes *activations* to MXFP4 — the ones the
# quantizer-health counters describe (weight-only and float linears leave
# activations untouched)
_ACT_QUANT_BACKENDS = ("mxfp4_ste", "mxfp4_ste_prequant", "cim_analog")

# Drift tolerances, both in *tail mass*. Row-Hist calibrates ``E_N`` at
# the max live block-output exponent and ``adc_fs`` at the max |column
# sum| over the calibration batches on the digital-matched path, so on
# calibration traffic neither block overflow nor ADC saturation occurs
# by construction — but the deployed hybrid feeds each layer activations
# perturbed by upstream ADC quantization, so a thin tail of live samples
# legitimately spills over (peaks overshoot full scale by up to ~25% and
# exponents by one notch on deep stacks, yet the spilled *fraction*
# stays under ~2% saturation / ~1% block overflow). Point verdicts on
# peak statistics would therefore false-positive; the detector instead
# reads tail mass against these tolerances, while the raw peak gauges
# (``fidelity_drift_exp_margin`` / ``fidelity_drift_fs_ratio``) stay
# published for dashboards. A genuinely mis-scaled layer lands far
# beyond both (adc_fs/4 -> >10% of samples saturated per layer).
SAT_DRIFT_TOL = 0.05
OVF_DRIFT_TOL = 0.02


def sqnr_db(ref, test) -> float:
    """Signal-to-quantization-noise ratio in dB (f64 accumulation).

    Zero-signal ``ref`` returns ``nan`` (documented sentinel): with no
    signal power the ratio is undefined, and dividing by the error floor
    would report a misleadingly huge dB value. Exact matches cap at the
    1e-30 error floor (> 200 dB)."""
    ref = np.asarray(ref, np.float64)
    err = np.asarray(test, np.float64) - ref
    sig = float((ref**2).mean())
    if sig == 0.0:
        return float("nan")
    return float(10 * np.log10(sig / max(float((err**2).mean()), 1e-30)))


def sqnr_trace(ref_caps: dict, test_caps: dict) -> dict:
    """Per-path SQNR between two activation captures (the dicts returned
    by ``models.calibrate.capture_linear_inputs`` for a reference and an
    instrumented run of the *same batch* — the tap's row subsampling is
    deterministic in shape, so entries compare element-for-element)."""
    out = {}
    for path in sorted(ref_caps):
        if path in test_caps and ref_caps[path].shape == test_caps[path].shape:
            out[path] = sqnr_db(ref_caps[path], test_caps[path])
    return out


def scale_adc_fs(tree, factor: float, match: str | None = None):
    """Copy of a cim-converted param tree with ``adc_fs`` leaves scaled by
    ``factor`` — the deliberate mis-calibration used by tests and the
    fidelity sweep to prove the saturation counters predict fidelity
    loss. ``match`` restricts scaling to nodes whose tree path contains
    the substring (stacked segments share one leaf per segment)."""

    def rec(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "adc_fs" and (match is None or match in path):
                    out[k] = v * factor
                else:
                    out[k] = rec(v, f"{path}/{k}" if path else k)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(
                rec(v, f"{path}/{i}" if path else str(i))
                for i, v in enumerate(node)
            )
        return node

    return rec(tree, "")


class FidelityProbe:
    """Host-side per-layer numerical-fidelity recorder.

    Attach via ``RunCtx.fidelity``; ``linear_apply`` calls
    :meth:`observe_linear` with the calibration path name for every named
    linear. Eager-only — fidelity runs execute with layers unrolled
    exactly like calibration capture, and the probe raises on tracers
    rather than silently recording garbage.

    All publishing funnels through the owning :class:`~repro.obs.Obs`
    handle and short-circuits when ``obs.enabled`` is ``False`` (the
    PR 7 disabled-mode contract), so a disabled probe costs one attribute
    check per linear.

    Published metric families (all labelled ``{layer=<path>}``):

    - ``fidelity_mxfp4_{values,clip,underflow}_total`` counters and the
      derived ``fidelity_mxfp4_{clip,underflow}_ratio`` gauges;
    - ``fidelity_block_exponent`` histogram (:data:`EXP_BUCKETS`);
    - ``adc_{saturation,samples}_total{pass=1|2}`` counters,
      ``adc_saturation_ratio`` / ``adc_fs_headroom`` gauges, and the
      ``adc_code_utilization`` histogram (:data:`RATIO_BUCKETS`);
    - ``fidelity_cim_{overflow,underflow}_ratio`` gauges (CM alignment);
    - ``fidelity_sqnr_db`` gauges via :meth:`note_sqnr`;
    - ``fidelity_drift_*`` via :meth:`drift_report`.
    """

    def __init__(self, obs=None, max_rows: int = 512):
        if obs is None:
            from repro.obs.tracing import Obs

            obs = Obs()
        self.obs = obs
        self.max_rows = max_rows
        self.records: dict = {}

    @property
    def registry(self):
        return self.obs.registry

    # ------------------------------------------------------ linear hook

    def observe_linear(self, path: str, ctx, params, x) -> None:
        if not self.obs.enabled:
            return
        import jax

        if isinstance(x, jax.core.Tracer):
            raise RuntimeError(
                "FidelityProbe is eager-only: fidelity runs execute "
                "unrolled outside jit (the calibration-capture regime); "
                f"got a tracer at layer {path!r}"
            )
        import jax.numpy as jnp

        from repro.core import mx as mxlib
        from repro.layers import backends as backends_lib

        if not isinstance(params, dict):
            return
        k = x.shape[-1]
        if k % mxlib.BLOCK:
            return
        backend = backends_lib.resolve_backend(ctx, params).name
        if backend not in _ACT_QUANT_BACKENDS:
            return  # activations stay float: nothing to probe
        xf = jnp.asarray(x).astype(jnp.float32).reshape(-1, k)
        if xf.shape[0] > self.max_rows:
            idx = np.linspace(0, xf.shape[0] - 1, self.max_rows).astype(int)
            xf = jnp.take(xf, jnp.asarray(idx), axis=0)

        lab = {"layer": path}
        rec = self.records.setdefault(path, {})
        self._observe_quant(lab, rec, xf, jax, mxlib)
        if "e_n" in params:  # resident analog node: ADC + alignment stats
            self._observe_cim(lab, rec, ctx, params, xf, jax, mxlib,
                              backends_lib)

    def _observe_quant(self, lab, rec, xf, jax, mxlib) -> None:
        h = jax.device_get(mxlib.quant_health(xf, EXP_BUCKETS))
        r = self.registry
        total, clip, under = (
            int(h["total"]), int(h["clipped"]), int(h["underflow"])
        )
        rec["act_total"] = rec.get("act_total", 0) + total
        rec["act_clipped"] = rec.get("act_clipped", 0) + clip
        rec["act_underflow"] = rec.get("act_underflow", 0) + under
        r.counter("fidelity_mxfp4_values_total",
                  "activation elements quantized", labels=lab).inc(total)
        r.counter("fidelity_mxfp4_clip_total",
                  "elements clipped to the E2M1 max magnitude",
                  labels=lab).inc(clip)
        r.counter("fidelity_mxfp4_underflow_total",
                  "nonzero elements flushed to zero by the block exponent",
                  labels=lab).inc(under)
        t = max(rec["act_total"], 1)
        r.gauge("fidelity_mxfp4_clip_ratio",
                "cumulative clip fraction", labels=lab
                ).set(rec["act_clipped"] / t)
        r.gauge("fidelity_mxfp4_underflow_ratio",
                "cumulative underflow fraction", labels=lab
                ).set(rec["act_underflow"] / t)
        r.histogram("fidelity_block_exponent",
                    "shared block exponents of live blocks (E8M0, unbiased)",
                    labels=lab, buckets=EXP_BUCKETS).merge_counts(
            h["exp_counts"], h["exp_sum"], h["exp_n"],
            h["exp_min"], h["exp_max"],
        )

    def _observe_cim(self, lab, rec, ctx, params, xf, jax, mxlib,
                     backends_lib) -> None:
        from repro.core import cim as cimlib

        cfg = backends_lib.cim_config(ctx)
        w = mxlib.MXW(params["codes"], params["exps"])
        calib = cimlib.LayerCalib(e_n=params["e_n"], adc_fs=params["adc_fs"])
        _, stats = cimlib.cim_linear_fidelity(
            xf, w, cfg, calib, code_buckets=RATIO_BUCKETS
        )
        stats = jax.device_get(stats)
        r = self.registry
        for pname in ("1", "2"):
            h = stats.get(f"pass{pname}")
            if h is None:
                continue
            sat, n = int(h["saturated"]), int(h["total"])
            rec["adc_saturated"] = rec.get("adc_saturated", 0) + sat
            rec["adc_samples"] = rec.get("adc_samples", 0) + n
            pl = dict(lab, **{"pass": pname})
            r.counter("adc_saturation_total",
                      "column sums clipped by the ADC range",
                      labels=pl).inc(sat)
            r.counter("adc_samples_total", "column sums through the ADC",
                      labels=pl).inc(n)
            r.histogram("adc_code_utilization",
                        "|ADC code| / half-range occupancy",
                        labels=lab, buckets=RATIO_BUCKETS).merge_counts(
                h["occ_counts"], h["occ_sum"], h["occ_n"],
                h["occ_min"], h["occ_max"],
            )
        r.gauge("adc_saturation_ratio",
                "cumulative ADC saturation fraction (both passes)",
                labels=lab).set(
            rec.get("adc_saturated", 0) / max(rec.get("adc_samples", 0), 1)
        )
        # drift raw material: the live analogues of what Row-Hist stored
        rec["e_n"] = int(params["e_n"])
        rec["adc_fs"] = float(params["adc_fs"])
        rec["live_fs"] = max(rec.get("live_fs", 0.0), float(stats["live_fs"]))
        rec["live_e_max"] = max(rec.get("live_e_max", -(10**6)),
                                int(stats["live_e_max"]))
        r.gauge("adc_fs_headroom",
                "calibrated full scale / live peak |column sum| (<1 means "
                "traffic exceeds calibration)", labels=lab).set(
            rec["adc_fs"] / rec["live_fs"] if rec["live_fs"] > 0
            else math.inf
        )
        over, und1, und2, live = (int(c) for c in stats["counts"])
        rec["blk_overflow"] = rec.get("blk_overflow", 0) + over
        rec["blk_under1"] = rec.get("blk_under1", 0) + und1
        rec["blk_under2"] = rec.get("blk_under2", 0) + und2
        rec["blk_live"] = rec.get("blk_live", 0) + live
        bl = max(rec["blk_live"], 1)
        r.gauge("fidelity_cim_overflow_ratio",
                "blocks shift-clamped above the CM window", labels=lab
                ).set(rec["blk_overflow"] / bl)
        r.gauge("fidelity_cim_underflow_ratio",
                "blocks zeroed below the pass-2 CM window", labels=lab
                ).set(rec["blk_under2"] / bl)

    # ------------------------------------------------------ SQNR + drift

    def note_sqnr(self, per_path: dict) -> None:
        """Fold per-layer SQNR (from :func:`sqnr_trace`) into the records
        and publish ``fidelity_sqnr_db{layer=...}`` gauges."""
        if not self.obs.enabled:
            return
        for path, db in per_path.items():
            self.records.setdefault(path, {})["sqnr_db"] = float(db)
            self.registry.gauge(
                "fidelity_sqnr_db",
                "per-layer SQNR vs the reference forward",
                labels={"layer": path},
            ).set(float(db))

    def drift_report(self, log=None, sat_tol: float = SAT_DRIFT_TOL,
                     ovf_tol: float = OVF_DRIFT_TOL) -> dict:
        """Compare live Row-Hist statistics against the stored per-layer
        calibration and publish drift gauges. A layer has *drifted* when
        live traffic exceeds what calibration provisioned for: more than
        ``ovf_tol`` of its live blocks overflowed the stored ``E_N``, or
        more than ``sat_tol`` of its ADC samples saturated (the full
        scale no longer covers the live column sums). The verdicts read
        tail mass — the peak statistics (``exp_margin`` / ``fs_ratio``)
        stay published as raw gauges, see :data:`SAT_DRIFT_TOL` for why.
        Self-consistent: Row-Hist calibrates at the max over the
        calibration batches, so replaying those batches never fires.

        Emits one structured warning per drifted layer and returns
        ``{"layers": {...}, "drifted": [...], "n_drifted": int}``."""
        if not self.obs.enabled:
            return {"layers": {}, "drifted": [], "n_drifted": 0}
        r = self.registry
        layers: dict = {}
        drifted: list = []
        for path in sorted(self.records):
            rec = self.records[path]
            if "e_n" not in rec:
                continue
            exp_margin = rec["e_n"] - rec["live_e_max"]
            fs_ratio = (rec["adc_fs"] / rec["live_fs"]
                        if rec["live_fs"] > 0 else math.inf)
            n = rec.get("adc_samples", 0)
            sat_ratio = rec.get("adc_saturated", 0) / n if n else 0.0
            live = rec.get("blk_live", 0)
            ovf_ratio = rec.get("blk_overflow", 0) / live if live else 0.0
            is_drifted = sat_ratio > sat_tol or ovf_ratio > ovf_tol
            lab = {"layer": path}
            r.gauge("fidelity_drift_exp_margin",
                    "stored E_N minus live max block-output exponent "
                    "(negative: drifted)", labels=lab).set(exp_margin)
            r.gauge("fidelity_drift_fs_ratio",
                    "calibrated ADC full scale / live peak (<1: drifted)",
                    labels=lab).set(fs_ratio)
            layers[path] = {
                "exp_margin": exp_margin,
                "fs_ratio": fs_ratio,
                "sat_ratio": sat_ratio,
                "ovf_ratio": ovf_ratio,
                "drifted": is_drifted,
            }
            if is_drifted:
                drifted.append(path)
                r.counter("fidelity_drift_total",
                          "layers whose live range exceeded calibration"
                          ).inc()
                (log or get_logger("repro.fidelity")).warning(
                    "calibration drift: %s",
                    kv(layer=path, exp_margin=exp_margin,
                       fs_ratio=fs_ratio, sat_ratio=sat_ratio,
                       ovf_ratio=ovf_ratio,
                       e_n=rec["e_n"], live_e_max=rec["live_e_max"],
                       adc_fs=rec["adc_fs"], live_fs=rec["live_fs"]),
                )
        return {"layers": layers, "drifted": drifted,
                "n_drifted": len(drifted)}

    def summary(self) -> dict:
        """JSON-able per-layer digest of everything recorded so far."""
        out: dict = {}
        for path in sorted(self.records):
            rec = self.records[path]
            e: dict = {}
            t = rec.get("act_total", 0)
            if t:
                e["clip_ratio"] = rec.get("act_clipped", 0) / t
                e["underflow_ratio"] = rec.get("act_underflow", 0) / t
            n = rec.get("adc_samples", 0)
            if n:
                e["adc_saturation_ratio"] = rec.get("adc_saturated", 0) / n
            if "e_n" in rec:
                e["exp_margin"] = rec["e_n"] - rec["live_e_max"]
                e["fs_headroom"] = (rec["adc_fs"] / rec["live_fs"]
                                    if rec["live_fs"] > 0 else math.inf)
            if "sqnr_db" in rec:
                e["sqnr_db"] = rec["sqnr_db"]
            out[path] = e
        return out


def run_fidelity_pass(
    ref_params,
    params,
    cfg,
    ctx,
    batch,
    *,
    obs=None,
    probe: FidelityProbe | None = None,
    forward_fn=None,
    ref_quant: str = "mxfp4_digital",
    quant: str = "cim",
    min_n: int = 32,
    max_rows: int = 512,
) -> tuple:
    """The full per-layer SQNR trace + health probe + drift check in two
    eager forwards of one batch:

    1. a *reference* forward of ``ref_params`` (the float tree) on the
       ``ref_quant`` backend, capturing per-linear input activations;
    2. an *instrumented* forward of ``params`` (the converted serving
       tree) with a :class:`FidelityProbe` attached, capturing at the
       same paths.

    Per-path SQNR between the captures (plus the model output) publishes
    as ``fidelity_sqnr_db{layer=...}``; the probe publishes quantizer /
    ADC health; :meth:`FidelityProbe.drift_report` closes the pass.
    Returns ``(probe, report)`` where ``report`` holds ``sqnr_db`` per
    path, the drift report, and the per-layer summary."""
    from repro.models import calibrate

    if probe is None:
        probe = FidelityProbe(obs=obs, max_rows=max_rows)
    ref_caps, ref_out = calibrate.capture_linear_inputs(
        ref_params, cfg, ctx, batch, quant=ref_quant,
        min_n=min_n, max_rows=max_rows, forward_fn=forward_fn,
    )
    caps, out = calibrate.capture_linear_inputs(
        params, cfg, ctx, batch, quant=quant,
        min_n=min_n, max_rows=max_rows, forward_fn=forward_fn,
        fidelity=probe,
    )
    per = sqnr_trace(ref_caps, caps)
    ref_y = ref_out[0] if isinstance(ref_out, tuple) else ref_out
    y = out[0] if isinstance(out, tuple) else out
    per["output"] = sqnr_db(np.asarray(ref_y, np.float64),
                            np.asarray(y, np.float64))
    probe.note_sqnr(per)
    drift = probe.drift_report()
    report = {"sqnr_db": per, "drift": drift, "layers": probe.summary()}
    return probe, report
