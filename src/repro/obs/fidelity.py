"""Shared fidelity metrics (numpy-only; no jax import at module load).

Home of ``sqnr_db`` — previously ``repro.core.metrics``, which now
re-exports from here for compatibility."""

from __future__ import annotations

import numpy as np


def sqnr_db(ref, test) -> float:
    """Signal-to-quantization-noise ratio in dB (f64 accumulation)."""
    ref = np.asarray(ref, np.float64)
    err = np.asarray(test, np.float64) - ref
    return float(
        10 * np.log10((ref**2).mean() / max((err**2).mean(), 1e-30))
    )
