"""Span-based request tracer + typed engine lifecycle events.

The serving engines emit typed events through an :class:`Obs` handle —
``enqueue -> admitted -> prefill/first-token -> per-decode-step ->
finish/evict`` for LM requests, one ``frame`` span per streamed image
for the vision engine — and the tracer turns them into per-request
metrics (TTFT, queue wait, per-token latency, end-to-end latency) plus
registry counters/gauges/histograms.

The old ad-hoc ``(kind, rids, n_tokens)`` tuple list survives as a
*derived view* (:meth:`Obs.legacy_trace`) so ``pipeline.simulate_trace``
and every existing consumer keep working unchanged.

``Obs(enabled=False)`` keeps the step-event record (the pre-PR trace
equivalent, needed by the pipeline model) but skips all per-request
span tracking and registry updates — the measured-overhead baseline.
"""

from __future__ import annotations

import dataclasses
import time

from repro.obs import registry as reg_mod


@dataclasses.dataclass(frozen=True)
class StepEvent:
    """One scheduled unit of engine work (a prefill, a decode step over
    the live lanes, or a streamed vision frame)."""

    kind: str  # prefill | decode | frame
    rids: tuple
    n_tokens: int
    t_start: float
    t_end: float

    @property
    def legacy(self) -> tuple:
        return (self.kind, self.rids, self.n_tokens)

    @property
    def wall_s(self) -> float:
        return self.t_end - self.t_start


@dataclasses.dataclass
class RequestMetrics:
    """Per-request span record, finalized at finish/evict."""

    rid: int
    n_prompt: int = 0
    t_enqueue: float = 0.0
    t_admitted: float | None = None
    t_first_token: float | None = None
    t_finish: float | None = None
    finish_reason: str | None = None
    token_times: list = dataclasses.field(default_factory=list)

    @property
    def n_generated(self) -> int:
        return len(self.token_times)

    @property
    def queue_wait_s(self) -> float | None:
        if self.t_admitted is None:
            return None
        return self.t_admitted - self.t_enqueue

    @property
    def ttft_s(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_enqueue

    @property
    def e2e_s(self) -> float | None:
        if self.t_finish is None:
            return None
        return self.t_finish - self.t_enqueue

    @property
    def token_intervals_s(self) -> list:
        """Inter-token gaps after the first token (decode cadence)."""
        t = self.token_times
        return [b - a for a, b in zip(t, t[1:])]


class Obs:
    """Telemetry handle threaded through the serving stack.

    Carries the metrics registry, the step-event record, per-request
    spans, and the kernel-profiling switches. Engines accept one at
    construction; ``None`` means "create a private enabled one", so
    telemetry is on by default without any caller changes.
    """

    def __init__(self, registry: reg_mod.MetricsRegistry | None = None,
                 enabled: bool = True, profile: bool = False,
                 clock=time.perf_counter):
        self.registry = registry or reg_mod.MetricsRegistry()
        self.enabled = enabled
        # profile=True additionally captures kernel wall clock via
        # block_until_ready in eager paths (see repro.obs.profile) —
        # off by default, it serializes dispatch
        self.profile = profile
        self.clock = clock
        self.steps: list[StepEvent] = []
        self.live: dict[int, RequestMetrics] = {}
        self.finished: list[RequestMetrics] = []

    # -------------------------------------------------- request lifecycle

    def request_enqueued(self, rid: int, n_prompt: int = 0,
                         t: float | None = None) -> None:
        if not self.enabled:
            return
        self.live[rid] = RequestMetrics(
            rid=rid, n_prompt=n_prompt,
            t_enqueue=self.clock() if t is None else t,
        )
        self.registry.counter(
            "serve_requests_total", "requests submitted to the engine"
        ).inc()

    def request_admitted(self, rid: int, t: float | None = None) -> None:
        if not self.enabled:
            return
        r = self.live.get(rid)
        if r is None or r.t_admitted is not None:
            return
        r.t_admitted = self.clock() if t is None else t
        self.registry.histogram(
            "serve_queue_wait_seconds", "enqueue -> admission wait"
        ).observe(r.queue_wait_s)

    def token_emitted(self, rid: int, t: float | None = None) -> None:
        if not self.enabled:
            return
        r = self.live.get(rid)
        if r is None:
            return
        t = self.clock() if t is None else t
        if r.t_first_token is None:
            r.t_first_token = t
            self.registry.histogram(
                "serve_ttft_seconds", "enqueue -> first token (host wall)"
            ).observe(r.ttft_s)
        else:
            self.registry.histogram(
                "serve_token_latency_seconds",
                "inter-token decode gap (host wall)",
            ).observe(t - r.token_times[-1])
        r.token_times.append(t)
        self.registry.counter(
            "serve_tokens_generated_total", "tokens emitted"
        ).inc()

    def request_finished(self, rid: int, reason: str = "max_new",
                         t: float | None = None) -> None:
        if not self.enabled:
            return
        r = self.live.pop(rid, None)
        if r is None:
            return
        r.t_finish = self.clock() if t is None else t
        r.finish_reason = reason
        self.finished.append(r)
        self.registry.counter(
            "serve_requests_finished_total", "completed requests by reason",
            labels={"reason": reason},
        ).inc()
        if reason == "page_exhausted":
            self.registry.counter(
                "serve_evictions_total",
                "requests evicted on KV-page exhaustion",
            ).inc()
        self.registry.histogram(
            "serve_request_latency_seconds", "enqueue -> finish (host wall)"
        ).observe(r.e2e_s)

    # ------------------------------------------------------- engine steps

    def step_recorded(self, kind: str, rids: tuple, n_tokens: int,
                      t_start: float, t_end: float,
                      lanes: int | None = None) -> None:
        """Record one scheduled step. Always kept (it is the pipeline
        model's input); registry updates only when enabled."""
        self.steps.append(StepEvent(kind, tuple(rids), n_tokens,
                                    t_start, t_end))
        if not self.enabled:
            return
        self.registry.counter(
            "serve_steps_total", "scheduled engine steps by kind",
            labels={"kind": kind},
        ).inc()
        self.registry.histogram(
            "serve_step_wall_seconds", "host wall per scheduled step",
            labels={"kind": kind},
        ).observe(t_end - t_start)
        if kind == "decode" and lanes:
            self.registry.histogram(
                "serve_decode_occupancy",
                "live lanes / total lanes per decode step",
                buckets=reg_mod.RATIO_BUCKETS,
            ).observe(len(rids) / lanes)

    def lanes_state(self, queued: int, active: int, free_slots: int) -> None:
        if not self.enabled:
            return
        self.registry.gauge("serve_queue_depth", "waiting requests").set(queued)
        self.registry.gauge("serve_active_lanes", "lanes decoding live work").set(active)
        self.registry.gauge("serve_free_slots", "free KV pool slots").set(free_slots)

    # ------------------------------------------------------ derived views

    def legacy_trace(self) -> list:
        """The pre-PR ``(kind, rids, n_tokens)`` tuple list, derived."""
        return [e.legacy for e in self.steps]

    def reset(self) -> None:
        """Drop recorded steps and finished spans (e.g. after a jit
        warmup run) — registered metric values are left alone."""
        self.steps.clear()
        self.finished.clear()
        self.live.clear()

    def request_summary(self) -> dict:
        """Percentile summary over finished requests (host wall)."""

        def pct(samples):
            if not samples:
                return None
            s = sorted(samples)

            def at(q):
                return s[min(int(q * len(s)), len(s) - 1)]

            return {"p50": at(0.5), "p90": at(0.9), "p99": at(0.99),
                    "mean": sum(s) / len(s), "n": len(s)}

        reqs = self.finished
        intervals = [iv for r in reqs for iv in r.token_intervals_s]
        return {
            "n_requests": len(reqs),
            "n_tokens": sum(r.n_generated for r in reqs),
            "ttft_s": pct([r.ttft_s for r in reqs if r.ttft_s is not None]),
            "queue_wait_s": pct(
                [r.queue_wait_s for r in reqs if r.queue_wait_s is not None]
            ),
            "token_latency_s": pct(intervals),
            "e2e_s": pct([r.e2e_s for r in reqs if r.e2e_s is not None]),
            "finish_reasons": _count_by(
                r.finish_reason for r in reqs
            ),
        }


def _count_by(items) -> dict:
    out: dict = {}
    for x in items:
        out[x] = out.get(x, 0) + 1
    return out
