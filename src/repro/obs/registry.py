"""Host-side metrics registry: counters, gauges, fixed-bucket histograms.

Pure Python, no new dependencies — the serving stack runs on the host
between jitted steps, so its telemetry is ordinary Python bookkeeping.
Metric families follow Prometheus conventions (a family = name + type +
help, holding one series per label set) so the text-exposition exporter
in ``repro.obs.export`` is a direct mapping.

Histograms use fixed bucket boundaries (cumulative-free storage: one
count per bucket plus sum/count/min/max) and extract p50/p90/p99 by
linear interpolation inside the winning bucket — the standard
``histogram_quantile`` estimator, bounded by the recorded min/max so
tiny sample counts don't report a bucket edge nobody observed.
"""

from __future__ import annotations

import bisect
import math
import threading

# latency histograms default to a geometric ladder from 1us to ~67s —
# wide enough for host wall times on CPU smoke boxes and simulated
# pipeline latencies alike
LATENCY_BUCKETS_S = tuple(1e-6 * 2.0**i for i in range(27))
# fractions (occupancy, utilization): linear 0..1
RATIO_BUCKETS = tuple(round(0.05 * i, 2) for i in range(1, 21))
# shared block exponents (E8M0, unbiased): unit ladder wide enough for
# bf16-scale model activations/weights; the +Inf bucket catches hotter
# blocks, everything colder piles into the first bucket
EXP_BUCKETS = tuple(float(e) for e in range(-24, 17))


class Counter:
    """Monotonic counter."""

    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self._value += v

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value."""

    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def dec(self, v: float = 1.0) -> None:
        self._value -= v

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with quantile extraction.

    ``buckets`` are the finite upper bounds (ascending); an implicit
    +Inf bucket catches the tail. ``quantile(q)`` interpolates linearly
    within the winning bucket, clamped to the observed [min, max].
    """

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets=LATENCY_BUCKETS_S):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError("buckets must be ascending and non-empty")
        self.buckets = b
        self.counts = [0] * (len(b) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge_counts(self, counts, sum, count, vmin, vmax) -> None:
        """Bulk-merge pre-bucketed counts computed elsewhere (the fidelity
        probes histogram whole tensors on device with the same boundaries
        and fold the result in with one call instead of one ``observe``
        per element). ``counts`` must already include the +Inf bucket."""
        if len(counts) != len(self.counts):
            raise ValueError(
                f"bucket count mismatch: got {len(counts)}, "
                f"have {len(self.counts)}"
            )
        count = int(count)
        if not count:
            return
        for i, c in enumerate(counts):
            self.counts[i] += int(c)
        self.sum += float(sum)
        self.count += count
        self.min = min(self.min, float(vmin))
        self.max = max(self.max, float(vmax))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Prometheus-style histogram_quantile, clamped to [min, max]."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} not in [0, 1]")
        if not self.count:
            return 0.0
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else self.max
                frac = (rank - (cum - c)) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
        return self.max

    def percentiles(self) -> dict:
        return {f"p{int(q * 100)}": self.quantile(q)
                for q in (0.5, 0.9, 0.99)}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One metric name: shared type/help, one child per label set."""

    __slots__ = ("name", "kind", "help", "buckets", "children")

    def __init__(self, name: str, kind: str, help: str = "",
                 buckets=LATENCY_BUCKETS_S):
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets = buckets
        self.children: dict[tuple, object] = {}

    def labels(self, labels: dict | None = None):
        key = tuple(sorted((labels or {}).items()))
        child = self.children.get(key)
        if child is None:
            child = (Histogram(self.buckets) if self.kind == "histogram"
                     else _KINDS[self.kind]())
            self.children[key] = child
        return child


class MetricsRegistry:
    """Get-or-create access to metric families.

    ``counter/gauge/histogram(name, help=..., labels=...)`` return the
    series for that label set directly, creating family and series on
    first touch; re-registering a name with a different type raises.
    """

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, kind: str, help: str, buckets) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help, buckets)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}"
                )
            elif help and not fam.help:
                fam.help = help
            return fam

    def counter(self, name: str, help: str = "",
                labels: dict | None = None) -> Counter:
        return self._family(name, "counter", help, None).labels(labels)

    def gauge(self, name: str, help: str = "",
              labels: dict | None = None) -> Gauge:
        return self._family(name, "gauge", help, None).labels(labels)

    def histogram(self, name: str, help: str = "",
                  labels: dict | None = None,
                  buckets=LATENCY_BUCKETS_S) -> Histogram:
        return self._family(name, "histogram", help, buckets).labels(labels)

    def families(self) -> list:
        return [self._families[k] for k in sorted(self._families)]

    def snapshot(self) -> dict:
        """JSON-able dump: {name: {type, help, series: [...]}} with
        histogram series carrying buckets, sum/count, and p50/p90/p99."""
        out = {}
        for fam in self.families():
            series = []
            for key, child in sorted(fam.children.items()):
                entry: dict = {"labels": dict(key)}
                if fam.kind == "histogram":
                    entry.update(
                        count=child.count,
                        sum=child.sum,
                        min=child.min if child.count else None,
                        max=child.max if child.count else None,
                        buckets=[
                            {"le": le, "count": c}
                            for le, c in zip(
                                list(fam.buckets) + ["+Inf"], child.counts
                            )
                        ],
                        **child.percentiles(),
                    )
                else:
                    entry["value"] = child.value
                series.append(entry)
            out[fam.name] = {
                "type": fam.kind, "help": fam.help, "series": series
            }
        return out
