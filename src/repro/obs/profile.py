"""Kernel profiling hooks: named timing scopes + optional wall capture.

Every kernel ops wrapper (``cim_linear``, ``mxfp4_matmul``,
``flash_attention``, ``paged_attention``) routes its dispatch through
:func:`profiled_call`:

- ``jax.named_scope`` always wraps the call, so the kernel shows up as a
  named region in HLO metadata and the jax profiler's trace viewer —
  this is trace-time-only and costs nothing at runtime.
- With an :class:`Obs` handle attached (``RunCtx.obs``), dispatches are
  additionally counted (``kernel_calls_total{kernel=,mode=}``) and
  bracketed with ``jax.profiler.TraceAnnotation`` for host-side TraceMe
  events.
- With ``obs.profile=True`` (the ``--profile`` flag; off by default),
  eager calls also capture wall clock via ``block_until_ready`` into
  the ``kernel_wall_seconds{kernel=}`` histogram. Inside a ``jax.jit``
  trace the result is an abstract tracer — blocking is impossible and
  meaningless — so traced calls only count (``mode="traced"``) and the
  op-level profile comes from the named scopes via the jax profiler.
"""

from __future__ import annotations

import time

import jax


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def profiled_call(name: str, obs, fn):
    """Run ``fn()`` under a named kernel scope; see module docstring."""
    if obs is None or not obs.enabled:
        with jax.named_scope(f"repro/{name}"):
            return fn()
    t0 = time.perf_counter()
    with jax.named_scope(f"repro/{name}"), \
            jax.profiler.TraceAnnotation(f"repro/{name}"):
        out = fn()
    traced = _is_tracer(out)
    obs.registry.counter(
        "kernel_calls_total", "kernel wrapper dispatches",
        labels={"kernel": name, "mode": "traced" if traced else "eager"},
    ).inc()
    if obs.profile and not traced:
        jax.block_until_ready(out)
        obs.registry.histogram(
            "kernel_wall_seconds",
            "eager kernel wall time (dispatch -> ready; --profile only)",
            labels={"kernel": name},
        ).observe(time.perf_counter() - t0)
    return out
