"""Structured logging for the serving launchers.

One compact line per record: ``HH:MM:SS.mmm L name| msg key=value ...``.
``get_logger`` configures a stream handler once per logger and is
idempotent; ``kv(...)`` renders a field dict in stable order so step
summaries stay grep-able (``live=3 tok_s=41.2 free_slots=2``).
"""

from __future__ import annotations

import logging

_FMT = "%(asctime)s.%(msecs)03d %(levelname).1s %(name)s| %(message)s"
_DATEFMT = "%H:%M:%S"

LEVELS = ("debug", "info", "warning", "error")


def get_logger(name: str = "repro.serve",
               level: str = "info") -> logging.Logger:
    if level not in LEVELS:
        raise ValueError(f"unknown log level {level!r} (one of {LEVELS})")
    logger = logging.getLogger(name)
    logger.setLevel(getattr(logging, level.upper()))
    if not logger.handlers:
        h = logging.StreamHandler()
        h.setFormatter(logging.Formatter(_FMT, datefmt=_DATEFMT))
        logger.addHandler(h)
        logger.propagate = False
    return logger


def kv(**fields) -> str:
    """Render fields as ``k=v`` pairs in insertion order; floats get a
    compact fixed precision."""
    parts = []
    for k, v in fields.items():
        if isinstance(v, float):
            v = f"{v:.4g}"
        parts.append(f"{k}={v}")
    return " ".join(parts)
