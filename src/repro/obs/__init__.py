"""Serving telemetry subsystem (pure Python host-side, no new deps).

- :mod:`repro.obs.registry` — counters / gauges / fixed-bucket
  histograms with p50/p90/p99 quantile extraction, grouped into
  Prometheus-style metric families.
- :mod:`repro.obs.tracing` — the :class:`Obs` handle the serving
  engines emit typed lifecycle events through (enqueue -> admitted ->
  prefill/first-token -> decode steps -> finish/evict), yielding TTFT,
  queue-wait, per-token latency, occupancy and eviction metrics; the
  old ``(kind, rids, n_tokens)`` tuple trace is a derived view.
- :mod:`repro.obs.profile` — named kernel timing scopes
  (``jax.named_scope`` + ``jax.profiler.TraceAnnotation``) with
  optional eager wall-clock capture behind ``Obs.profile``.
- :mod:`repro.obs.export` — Prometheus text exposition + JSON snapshot
  writers (and the parser the round-trip test uses).
- :mod:`repro.obs.slo` — configurable TTFT / per-token latency targets
  scored over finished-request spans.
- :mod:`repro.obs.fidelity` — numerical-fidelity observability:
  ``sqnr_db`` / per-layer SQNR tracing, the :class:`FidelityProbe`
  (MXFP4 clip/underflow counters, ADC saturation + code-utilization
  histograms via ``RunCtx.fidelity``), and the calibration-drift
  detector comparing live Row-Hist statistics against stored
  ``LayerCalib``.
"""

from repro.obs.export import (  # noqa: F401
    parse_prometheus,
    to_json,
    to_prometheus,
    write_metrics,
)
from repro.obs.fidelity import (  # noqa: F401
    FidelityProbe,
    run_fidelity_pass,
    scale_adc_fs,
    sqnr_db,
    sqnr_trace,
)
from repro.obs.log import get_logger, kv  # noqa: F401
from repro.obs.profile import profiled_call  # noqa: F401
from repro.obs.registry import (  # noqa: F401
    EXP_BUCKETS,
    LATENCY_BUCKETS_S,
    RATIO_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.slo import SLOTargets, evaluate_slo  # noqa: F401
from repro.obs.tracing import Obs, RequestMetrics, StepEvent  # noqa: F401
