"""SLO evaluation over finished-request telemetry.

``SLOTargets`` names configurable latency objectives (TTFT and
per-token, p50 and p99, in seconds; ``None`` disables a check) and
``evaluate_slo`` scores a set of :class:`RequestMetrics` spans against
them: per-check observed-vs-target pass/fail, per-request/per-interval
violation counts against the p99 targets, and an overall verdict. The
serving benchmark folds the report into ``BENCH_serving.json``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SLOTargets:
    ttft_p50_s: float | None = None
    ttft_p99_s: float | None = None
    token_p50_s: float | None = None
    token_p99_s: float | None = None

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


def _pct(samples: list, q: float) -> float | None:
    if not samples:
        return None
    s = sorted(samples)
    return s[min(int(q * len(s)), len(s) - 1)]


def evaluate_slo(requests, targets: SLOTargets) -> dict:
    """Score finished requests against the targets.

    Returns ``{targets, observed, checks, violations, pass}``; a check
    with no samples reports ``ok=None`` and does not fail the verdict.
    """
    ttft = [r.ttft_s for r in requests if r.ttft_s is not None]
    tokens = [iv for r in requests for iv in r.token_intervals_s]
    observed = {
        "ttft_p50_s": _pct(ttft, 0.5),
        "ttft_p99_s": _pct(ttft, 0.99),
        "token_p50_s": _pct(tokens, 0.5),
        "token_p99_s": _pct(tokens, 0.99),
    }
    checks = {}
    for key, target in targets.asdict().items():
        if target is None:
            continue
        got = observed[key]
        checks[key] = {
            "target_s": target,
            "observed_s": got,
            "ok": None if got is None else got <= target,
        }
    violations = {}
    if targets.ttft_p99_s is not None:
        violations["ttft_over_p99_target"] = sum(
            1 for v in ttft if v > targets.ttft_p99_s
        )
    if targets.token_p99_s is not None:
        violations["tokens_over_p99_target"] = sum(
            1 for v in tokens if v > targets.token_p99_s
        )
    return {
        "targets": targets.asdict(),
        "observed": observed,
        "checks": checks,
        "violations": violations,
        "pass": all(c["ok"] is not False for c in checks.values()),
    }
