"""Metrics exporters: Prometheus text exposition + JSON snapshots.

``to_prometheus`` renders a :class:`MetricsRegistry` in the Prometheus
text exposition format (format 0.0.4: HELP/TYPE headers, cumulative
``_bucket{le=...}`` series plus ``_sum``/``_count`` for histograms).
``parse_prometheus`` is the matching reader used by the tier-1
round-trip test and by ``scripts/metrics_summary.py`` — it returns
``{(name, ((label, value), ...)): float}`` samples.

``write_metrics(registry, path)`` writes the JSON snapshot at ``path``
and the Prometheus exposition next to it (``.prom`` suffix).
"""

from __future__ import annotations

import json
import math
import os

from repro.obs.registry import MetricsRegistry


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"  # Prometheus text-format spelling (zero-signal SQNR)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def to_prometheus(registry: MetricsRegistry) -> str:
    lines: list[str] = []
    for fam in registry.families():
        if fam.help:
            lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for key, child in sorted(fam.children.items()):
            labels = dict(key)
            if fam.kind == "histogram":
                cum = 0
                for le, c in zip(list(fam.buckets) + [math.inf],
                                 child.counts):
                    cum += c
                    ll = dict(labels)
                    ll["le"] = "+Inf" if math.isinf(le) else _fmt_value(le)
                    lines.append(
                        f"{fam.name}_bucket{_fmt_labels(ll)} {cum}"
                    )
                lines.append(
                    f"{fam.name}_sum{_fmt_labels(labels)} "
                    f"{_fmt_value(child.sum)}"
                )
                lines.append(
                    f"{fam.name}_count{_fmt_labels(labels)} {child.count}"
                )
            else:
                lines.append(
                    f"{fam.name}{_fmt_labels(labels)} "
                    f"{_fmt_value(child.value)}"
                )
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Parse a text exposition back into ``{(name, labels_tuple): value}``.

    Covers exactly what ``to_prometheus`` emits (one sample per line,
    HELP/TYPE comments) — a format round-trip check, not a general
    Prometheus client."""
    samples: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "{" in line:
            name, rest = line.split("{", 1)
            labelstr, valstr = rest.rsplit("}", 1)
            labels = []
            for part in _split_labels(labelstr):
                k, v = part.split("=", 1)
                labels.append((k, _unescape(v.strip('"'))))
            key = (name, tuple(sorted(labels)))
        else:
            name, valstr = line.rsplit(None, 1)
            key = (name, ())
            valstr = " " + valstr
        v = valstr.strip()
        samples[key] = math.inf if v == "+Inf" else float(v)  # float("NaN") ok
    return samples


def _split_labels(s: str) -> list:
    out, cur, in_str = [], "", False
    for ch in s:
        if ch == '"' and not cur.endswith("\\"):
            in_str = not in_str
        if ch == "," and not in_str:
            out.append(cur)
            cur = ""
        else:
            cur += ch
    if cur:
        out.append(cur)
    return out


def _unescape(s: str) -> str:
    return (
        s.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\")
    )


def _sanitize(obj):
    """NaN -> None, recursively: ``json.dump`` would emit a bare ``NaN``
    token (invalid strict JSON) for zero-signal SQNR gauges otherwise."""
    if isinstance(obj, float) and math.isnan(obj):
        return None
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    return obj


def to_json(registry: MetricsRegistry, extra: dict | None = None) -> dict:
    out = {"metrics": _sanitize(registry.snapshot())}
    if extra:
        out.update(_sanitize(extra))
    return out


def write_metrics(registry: MetricsRegistry, path: str,
                  extra: dict | None = None) -> tuple:
    """Write the JSON snapshot at ``path`` and the Prometheus text
    exposition beside it; returns (json_path, prom_path)."""
    snap = to_json(registry, extra)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, default=_json_default)
    prom_path = os.path.splitext(path)[0] + ".prom"
    with open(prom_path, "w") as f:
        f.write(to_prometheus(registry))
    return path, prom_path


def _json_default(o):
    if isinstance(o, float):
        return o
    return str(o)
