"""MXFP4 microscaling numerics (OCP MX spec, paper §2.3 + Appendix A).

A length-``k`` (k = 32) block is stored as 32 E2M1 ("FP4") private elements
plus one shared E8M0 power-of-two scale:  V_i = P_i * 2^E.

Internally we carry FP4 elements as *integer codes* equal to ``2 * P_i``,
i.e. values in ``{0, ±1, ±2, ±3, ±4, ±6, ±8, ±12}`` — exactly the paper's
lossless INT5 affine encoding of FP4 (activations use the signed [-12, 12]
code directly; weights add the bias ``w_b = 12`` to land in [0, 24]).

All functions are jit-friendly pure jnp.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 32  # MX block size along the contraction axis
EMAX_ELEM = 2  # largest E2M1 exponent (6 = 1.5 * 2^2)
FP4_MAX = 6.0
CODE_MAX = 12  # 2 * FP4_MAX
WEIGHT_BIAS = 12  # INT5 affine bias for unsigned weight encoding
E8M0_MIN, E8M0_MAX = -127, 127

# |code| -> E2M1 nibble (sign bit added separately):  value = code / 2
#   e=0: {0, 0.5}; e=1: {1, 1.5}; e=2: {2, 3}; e=3: {4, 6}
_ABS_CODE_TO_NIBBLE = jnp.array(
    [0, 1, 2, 3, 4, 0, 5, 0, 6, 0, 0, 0, 7], dtype=jnp.uint8
)  # index = |code|, valid only at {0,1,2,3,4,6,8,12}
_NIBBLE_TO_CODE = jnp.array([0, 1, 2, 3, 4, 6, 8, 12], dtype=jnp.int8)


class MX(NamedTuple):
    """A block-quantized tensor. ``codes`` has the (zero-padded) original
    shape; ``exps`` replaces the quantized axis (last) by n_blocks.

    value[..., b*32 + i] = codes[..., b*32 + i] / 2 * 2^exps[..., b]
    """

    codes: jax.Array  # int8 in [-12, 12], shape [..., K_pad]
    exps: jax.Array  # int8 unbiased E8M0 exponent, shape [..., K_pad // 32]


def exp2i(e: jax.Array) -> jax.Array:
    """Exact 2^e (float32) for integer-valued ``e`` via exponent-field
    bit construction. ``jnp.exp2`` is only ~1-ulp accurate on CPU (it
    lowers to ``exp(x*ln2)``), which breaks bit-exactness; this is exact
    for e in [-252, 252] (split into two factors to cover beyond the
    single-factor [-126, 127] range)."""
    e = jnp.asarray(e, jnp.int32)
    h1 = jnp.clip(e // 2, -126, 127)
    h2 = jnp.clip(e - h1, -126, 127)

    def f(h):
        return jax.lax.bitcast_convert_type(
            ((h + 127) << 23).astype(jnp.int32), jnp.float32
        )

    return f(h1) * f(h2)


def floor_ilog2(x: jax.Array) -> jax.Array:
    """Exact ``floor(log2(x))`` (int32) for finite ``x >= 0`` read straight
    from the IEEE-754 exponent field — no ``log2``/``floor`` transcendentals.

    ``jnp.log2`` is not correctly rounded: for ``x`` one ulp *below* a
    power of two it rounds up to the integer, so ``floor(log2(x))`` built
    from it overshoots by one (measured on CPU). This version is the true
    floor everywhere normal. Zero and subnormal inputs read as exponents
    ``<= -127``; every caller here clamps at the E8M0 floor (-127) or at
    the E2M1 element floor (0), so the exact subnormal exponent never
    matters.
    """
    bits = jax.lax.bitcast_convert_type(
        jnp.asarray(x, jnp.float32), jnp.int32
    )
    return ((bits >> 23) & 0xFF) - 127


def _pad_last(x: jax.Array, multiple: int = BLOCK) -> jax.Array:
    k = x.shape[-1]
    rem = (-k) % multiple
    if rem:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
        x = jnp.pad(x, pad)
    return x


def _quant_scaled(xb: jax.Array):
    """Shared bit-level core of :func:`quantize` / :func:`fake_quant`.

    ``xb``: f32 blocks [..., nb, 32]. Returns ``(code_mag, ebf)`` where
    ``code_mag`` f32 [..., nb, 32] is ``2 * |fp4|`` in {0..12} and ``ebf``
    int32 [..., nb, 1] is the *biased* shared exponent field, clipped to
    [2, 254] so ``e_shared = ebf - 129`` covers exactly the E8M0 range
    [-127, 125] (zero / subnormal amax lands on the -127 floor, matching
    the OCP zero-block rule). Everything runs on IEEE-754 fields — one
    reduce plus a short fuseable elementwise chain, no transcendentals.
    """
    ax = jnp.abs(xb)
    amax = jnp.max(ax, axis=-1, keepdims=True)
    ebf = jnp.clip(
        (jax.lax.bitcast_convert_type(amax, jnp.int32) >> 23) & 0xFF, 2, 254
    )
    # y = |x| * 2^(129 - ebf) = |x| / 2^e_shared, exact power-of-two mul
    y = ax * jax.lax.bitcast_convert_type((256 - ebf) << 23, jnp.float32)
    # E2M1 round ties-to-even on the local grid (binade from the field)
    e = jnp.clip(
        (jax.lax.bitcast_convert_type(y, jnp.int32) >> 23) - 127, 0, EMAX_ELEM
    )
    q = jnp.rint(y * jax.lax.bitcast_convert_type((128 - e) << 23, jnp.float32))
    q = q * jax.lax.bitcast_convert_type((126 + e) << 23, jnp.float32)
    q = jnp.minimum(q, FP4_MAX)
    return 2.0 * q, ebf


def quantize_e2m1(y: jax.Array) -> jax.Array:
    """Round ``y`` to the E2M1 grid (round-to-nearest-even), returning
    integer codes ``2 * fp4`` as int8. Input must already be scaled.

    Transcendental-free: the local grid binade comes from the IEEE
    exponent field (:func:`floor_ilog2`) and the grid divide is an exact
    power-of-two multiply. Zero maps to exponent <= -127, clamped to the
    e=0 (step 0.5) grid, where ``rint(0) == 0``.
    """
    ay = jnp.abs(y)
    # piecewise grid step: 0.5 for |y|<2, 1 for [2,4), 2 for [4,6]
    e = jnp.clip(floor_ilog2(ay), 0, EMAX_ELEM)
    # ties-to-even on the local grid; ay/step == ay * 2^(1-e) exactly
    q = jnp.rint(ay * exp2i(1 - e)) * exp2i(e - 1)
    q = jnp.minimum(q, FP4_MAX)
    code = jnp.sign(y) * (2.0 * q)
    return code.astype(jnp.int8)


def quantize(x: jax.Array, axis: int = -1) -> MX:
    """Block-quantize ``x`` to MXFP4 along ``axis`` (padded to 32).

    This *is* the quantize-to-codes entry point: the returned :class:`MX`
    lives in the lossless INT5 code domain, so consumers that want codes
    (the CIM datapath, the quantized-resident KV cache, packed serving
    weights) take it directly with no dequantize round-trip;
    :func:`fake_quant` composes it with :func:`dequantize` for value-domain
    consumers.

    The shared exponent is extracted from the IEEE-754 exponent field of
    the block amax (exact ``floor(log2)``) — no transcendentals. Note
    ``jnp.log2`` is *not* correctly rounded at inputs one ulp below a
    power of two (it rounds up, skipping the OCP clamp-at-6 there); this
    implementation is the exact OCP MX rule everywhere. Inputs on the
    bf16 grid — all model activations/weights here — cannot land in that
    one-ulp window, so the two rules are bitwise identical on model data.
    """
    x = jnp.moveaxis(x, axis, -1) if axis not in (-1, x.ndim - 1) else x
    x = _pad_last(x.astype(jnp.float32))
    shp = x.shape
    xb = x.reshape(shp[:-1] + (shp[-1] // BLOCK, BLOCK))
    code_mag, ebf = _quant_scaled(xb)
    codes = jnp.where(xb < 0, -code_mag, code_mag).astype(jnp.int8)
    return MX(codes.reshape(shp), (ebf[..., 0] - 129).astype(jnp.int8))


def quantize_axis(x: jax.Array, axis: int) -> MX:
    """Code-domain :func:`quantize` along an arbitrary axis: the quantized
    axis is *moved to the end* of both ``codes`` and ``exps`` (callers that
    keep resident codes want the block axis last — e.g. the KV cache's
    per-key-block V codes)."""
    if axis in (-1, x.ndim - 1):
        return quantize(x)
    return quantize(jnp.moveaxis(x, axis, -1))


def dequantize(mx: MX, out_len: int | None = None, dtype=jnp.float32) -> jax.Array:
    shp = mx.codes.shape
    cb = mx.codes.reshape(shp[:-1] + (shp[-1] // BLOCK, BLOCK))
    v = cb.astype(jnp.float32) * 0.5 * exp2i(mx.exps)[..., None]
    v = v.reshape(shp)
    if out_len is not None and out_len != shp[-1]:
        v = v[..., :out_len]
    return v.astype(dtype)


def encode_weight_unsigned(mx: MX) -> jax.Array:
    """INT5 affine map of weight codes into [0, 24] (uint8)."""
    return (mx.codes.astype(jnp.int16) + WEIGHT_BIAS).astype(jnp.uint8)


def decode_weight_unsigned(u: jax.Array) -> jax.Array:
    return (u.astype(jnp.int16) - WEIGHT_BIAS).astype(jnp.int8)


# ---------------------------------------------------------------- packing

def pack_codes(codes: jax.Array) -> jax.Array:
    """Pack int8 codes (2*fp4) into E2M1 nibbles, two per uint8.

    Nibble layout: [sign(1) | exp(2) | man(1)]; even element in low nibble.
    Last axis must be even (blocks of 32 always are).
    """
    sign = (codes < 0).astype(jnp.uint8)
    mag = jnp.abs(codes.astype(jnp.int32))
    nib = _ABS_CODE_TO_NIBBLE[mag] | (sign << 3)
    lo, hi = nib[..., 0::2], nib[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_codes(packed: jax.Array) -> jax.Array:
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    nib = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))
    mag = _NIBBLE_TO_CODE[(nib & 0x7).astype(jnp.int32)]
    sign = jnp.where((nib >> 3) & 1, -1, 1).astype(jnp.int8)
    return (sign * mag).astype(jnp.int8)


def _build_pair_table() -> np.ndarray:
    """256-entry byte -> uint32 table: low/high u16 halves hold the bf16
    bit patterns of the two E2M1 *code* values (2 * fp4 in [-12, 12]) a
    packed byte carries (even element in the low nibble). One gather + one
    bitcast decodes a whole byte — the per-nibble shift/select chain of
    :func:`unpack_codes` was the dominant cost of jnp dequant on CPU."""
    byte = np.arange(256)

    def val(nib):
        m = nib & 1
        e = (nib >> 1) & 3
        c = np.where(e == 0, m, (2 + m) << np.maximum(e - 1, 0))
        return np.where((nib >> 3) & 1, -c, c).astype(np.float32)

    def bf16_bits(v):  # round-to-nearest is exact for these integers
        return (v.astype(">f4").view(">u4") >> 16).astype(np.uint32)

    return bf16_bits(val(byte & 15)) | (bf16_bits(val(byte >> 4)) << 16)


PAIR_TABLE = _build_pair_table()


def unpack_pairs_bf16(packed: jax.Array, table: jax.Array | None = None
                      ) -> jax.Array:
    """Packed uint8 nibble pairs [..., K//2] -> bf16 *code* values
    (``2 * fp4``) [..., K] through :data:`PAIR_TABLE`: one gather + one
    bitcast per byte, no shift/select chain. Element ``2i`` comes from the
    low nibble of byte ``i`` (the :func:`pack_codes` layout). ``table``
    lets Pallas kernels thread the table in as an operand (kernels cannot
    capture array constants)."""
    if table is None:
        table = jnp.asarray(PAIR_TABLE)
    pair = table[packed.astype(jnp.int32)]  # [..., K//2]
    u16 = jax.lax.bitcast_convert_type(pair, jnp.uint16)  # [..., K//2, 2] LE
    cb = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)
    return cb.reshape(packed.shape[:-1] + (-1,))


def exps_to_biased(exps: jax.Array) -> jax.Array:
    """Unbiased int8 exponent -> biased uint8 (E8M0 storage)."""
    return (exps.astype(jnp.int16) + 127).astype(jnp.uint8)


def exps_from_biased(b: jax.Array) -> jax.Array:
    return (b.astype(jnp.int16) - 127).astype(jnp.int8)


# ------------------------------------------------------------- fake quant

def _bf16_pow2(field: jax.Array) -> jax.Array:
    """bf16 with exponent *field* ``field`` (int32 in [0, 255]) — bf16
    shares IEEE-754's 8-bit exponent layout at bit 7."""
    return jax.lax.bitcast_convert_type(
        (field << 7).astype(jnp.uint16), jnp.bfloat16
    )


@jax.custom_vjp
def fake_quant(x: jax.Array) -> jax.Array:
    """Quantize-dequantize along the last axis with a straight-through
    estimator (QAT-style). Shape is preserved (pad/unpad internally).
    Bitwise ``dequantize(quantize(x))`` without the int8 code round-trip;
    bf16 inputs run the chain natively in bf16 (see
    :func:`_fake_quant_impl`)."""
    return _fake_quant_impl(x, x.ndim - 1)


def _fq_fwd(x):
    return fake_quant(x), None


def _fq_bwd(_, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant_axis(x: jax.Array, axis: int) -> jax.Array:
    """:func:`fake_quant` along an arbitrary axis (STE gradient)."""
    return _fake_quant_impl(x, axis)


def _fake_quant_impl(x: jax.Array, axis: int) -> jax.Array:
    """The one MXFP4 quantize-dequantize chain, computed *in layout*: the
    quantized axis reshapes in place to (nb, 32) and everything broadcasts
    over it — no moveaxis transposes, bitwise the moved-axis composition
    (identical elements, blocks and ops; reductions are order-free).

    bf16 inputs run natively in bf16: every E2M1 grid value, tie point and
    power-of-two scale is exactly bf16-representable (bf16 shares the
    8-bit IEEE exponent layout), so the decisions — and the values, both
    paths flushing the sub-2^-126 scale window to zero on CPU — are
    bitwise ``chain(x.astype(f32)).astype(bf16)`` without the f32
    round-trip that doubled the SDPA operand traffic."""
    a = axis % x.ndim
    k = x.shape[a]
    rem = (-k) % BLOCK
    bf16 = x.dtype == jnp.bfloat16  # bf16-native (see _fake_quant_bf16)
    xf = x if bf16 else x.astype(jnp.float32)
    if rem:
        pad = [(0, 0)] * x.ndim
        pad[a] = (0, rem)
        xf = jnp.pad(xf, pad)
    shp = xf.shape
    xb = xf.reshape(shp[:a] + ((k + rem) // BLOCK, BLOCK) + shp[a + 1:])

    def field(t):  # biased IEEE exponent field (bf16 and f32 share it)
        if bf16:
            b = jax.lax.bitcast_convert_type(t, jnp.uint16).astype(jnp.int32)
            return (b >> 7) & 0xFF
        return (jax.lax.bitcast_convert_type(t, jnp.int32) >> 23) & 0xFF

    def pow2(f):  # value with exponent field f (int32)
        if bf16:
            return _bf16_pow2(f)
        return jax.lax.bitcast_convert_type(f << 23, jnp.float32)

    ax = jnp.abs(xb)
    amax = jnp.max(ax, axis=a + 1, keepdims=True)
    ebf = jnp.clip(field(amax), 2, 254)
    y = ax * pow2(256 - ebf)
    e = jnp.clip(field(y) - 127, 0, EMAX_ELEM)
    q = jnp.rint(y * pow2(128 - e))
    q = q * pow2(126 + e)
    q = jnp.minimum(q, jnp.asarray(FP4_MAX, xb.dtype))
    scale = _bf16_pow2(ebf - 2) if bf16 else exp2i(ebf - 129)
    v = jnp.where(xb < 0, -q, q) * scale  # q * 2^e_shared
    v = v.reshape(shp)
    if rem:
        v = jax.lax.slice_in_dim(v, 0, k, axis=a)
    return v.astype(x.dtype)


def _fqa_fwd(x, axis):
    return fake_quant_axis(x, axis), None


def _fqa_bwd(axis, _, g):
    return (g,)


fake_quant_axis.defvjp(_fqa_fwd, _fqa_bwd)


# ------------------------------------------------- fidelity observability

def bucket_counts(v: jax.Array, buckets: tuple, weights: jax.Array | None = None):
    """Bucket ``v`` on the boundaries ``buckets`` with the exact semantics
    of ``Histogram.observe`` (``bisect_left``: a value equal to a boundary
    lands in that ``le`` bucket; the implicit +Inf bucket catches the
    tail). Returns int32 counts of length ``len(buckets) + 1`` ready for
    ``Histogram.merge_counts``. ``weights`` (0/1) masks elements out."""
    b = jnp.asarray(buckets, jnp.float32)
    idx = jnp.searchsorted(b, v.astype(jnp.float32).ravel(), side="left")
    w = (jnp.ones(idx.shape, jnp.int32) if weights is None
         else weights.ravel().astype(jnp.int32))
    return jnp.zeros((len(buckets) + 1,), jnp.int32).at[idx].add(w)


@functools.partial(jax.jit, static_argnames=("exp_buckets",))
def quant_health(x: jax.Array, exp_buckets: tuple = ()) -> dict:
    """MXFP4 quantizer health over one tensor (last-axis blocks) — the
    fidelity-observability companion to :func:`quantize`; never on the
    hot path, the forward keeps calling :func:`quantize`/:func:`fake_quant`
    untouched.

    Reports, over the unpadded elements (padding is all-zero and zeros
    are neither clipped nor counted as underflow):

    - ``clipped``: values beyond the top of the E2M1 grid,
      ``|x| > 6 * 2^E`` — saturated to max magnitude by the OCP clamp;
    - ``underflow``: nonzero values flushed to code 0 by the shared
      block exponent (the block amax set ``E`` too hot for them);
    - ``total``: element count (static Python int);

    plus the shared-exponent distribution over *live* (nonzero-amax)
    blocks, bucketed on ``exp_buckets`` for ``Histogram.merge_counts``:
    ``exp_counts`` / ``exp_sum`` / ``exp_n`` / ``exp_min`` / ``exp_max``.
    """
    xf = _pad_last(jnp.asarray(x).astype(jnp.float32))
    shp = xf.shape
    xb = xf.reshape(shp[:-1] + (shp[-1] // BLOCK, BLOCK))
    code_mag, ebf = _quant_scaled(xb)
    scale = exp2i(ebf - 129)  # 2^e_shared, exact
    clipped = jnp.sum(jnp.abs(xb) > FP4_MAX * scale)
    underflow = jnp.sum((xb != 0) & (code_mag == 0))

    e = ebf[..., 0] - 129  # [..., nb] shared exponent per block
    live = jnp.any(xb != 0, axis=-1)  # zero-amax blocks sit on the floor
    n_live = jnp.sum(live)
    big = jnp.int32(10**6)
    return {
        "total": int(np.prod(x.shape)),
        "clipped": clipped,
        "underflow": underflow,
        "exp_counts": bucket_counts(e, exp_buckets, weights=live),
        "exp_sum": jnp.sum(jnp.where(live, e, 0)),
        "exp_n": n_live,
        "exp_min": jnp.min(jnp.where(live, e, big)),
        "exp_max": jnp.max(jnp.where(live, e, -big)),
    }


# ------------------------------------------------------------ bf16 helper

def to_bf16(x: jax.Array) -> jax.Array:
    return x.astype(jnp.bfloat16)


def mx_dot_bf16(a: MX, b: MX, bf16_partials: bool = False) -> jax.Array:
    """Digital-path dot product a @ b with MXFP4 operands and BF16-style
    accumulation semantics (paper §4.5).

    a: quantized along last axis, codes [..., K]; b: quantized along FIRST
    axis of a [K, N] weight (codes [K, N], exps [Kb, N] — produced by
    ``quantize(w.T).T``-style helpers below).

    With ``bf16_partials`` the per-32-block partial sums are rounded to
    BF16 before the cross-block accumulation (emulating the systolic
    array's BF16 accumulator at block granularity); otherwise f32
    accumulation with a final bf16 round (fast path).
    """
    va = dequantize(a)  # [..., K]
    vb = dequantize_w(b)  # [K, N]
    K = vb.shape[0]
    if bf16_partials:
        nb = K // BLOCK
        vab = va[..., :K].reshape(va.shape[:-1] + (nb, BLOCK))
        vbb = vb.reshape(nb, BLOCK, -1)
        parts = jnp.einsum("...bk,bkn->...bn", vab, vbb)
        parts = parts.astype(jnp.bfloat16).astype(jnp.float32)
        return jnp.sum(parts, axis=-2).astype(jnp.bfloat16)
    return jnp.matmul(va[..., :K], vb).astype(jnp.bfloat16)


class MXW(NamedTuple):
    """Weight matrix [K, N] quantized along K (contraction axis).

    codes: int8 [K_pad, N]; exps: int8 [K_pad//32, N].
    """

    codes: jax.Array
    exps: jax.Array


def quantize_w(w: jax.Array) -> MXW:
    """Quantize a [K, N] weight along K (axis 0)."""
    mx = quantize(w.T)  # blocks along K
    return MXW(jnp.swapaxes(mx.codes, -1, -2), jnp.swapaxes(mx.exps, -1, -2))


def dequantize_w(w: MXW, dtype=jnp.float32) -> jax.Array:
    mx = MX(jnp.swapaxes(w.codes, -1, -2), jnp.swapaxes(w.exps, -1, -2))
    return jnp.swapaxes(dequantize(mx, dtype=dtype), -1, -2)
