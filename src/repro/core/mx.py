"""MXFP4 microscaling numerics (OCP MX spec, paper §2.3 + Appendix A).

A length-``k`` (k = 32) block is stored as 32 E2M1 ("FP4") private elements
plus one shared E8M0 power-of-two scale:  V_i = P_i * 2^E.

Internally we carry FP4 elements as *integer codes* equal to ``2 * P_i``,
i.e. values in ``{0, ±1, ±2, ±3, ±4, ±6, ±8, ±12}`` — exactly the paper's
lossless INT5 affine encoding of FP4 (activations use the signed [-12, 12]
code directly; weights add the bias ``w_b = 12`` to land in [0, 24]).

All functions are jit-friendly pure jnp.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 32  # MX block size along the contraction axis
EMAX_ELEM = 2  # largest E2M1 exponent (6 = 1.5 * 2^2)
FP4_MAX = 6.0
CODE_MAX = 12  # 2 * FP4_MAX
WEIGHT_BIAS = 12  # INT5 affine bias for unsigned weight encoding
E8M0_MIN, E8M0_MAX = -127, 127

# |code| -> E2M1 nibble (sign bit added separately):  value = code / 2
#   e=0: {0, 0.5}; e=1: {1, 1.5}; e=2: {2, 3}; e=3: {4, 6}
_ABS_CODE_TO_NIBBLE = jnp.array(
    [0, 1, 2, 3, 4, 0, 5, 0, 6, 0, 0, 0, 7], dtype=jnp.uint8
)  # index = |code|, valid only at {0,1,2,3,4,6,8,12}
_NIBBLE_TO_CODE = jnp.array([0, 1, 2, 3, 4, 6, 8, 12], dtype=jnp.int8)


class MX(NamedTuple):
    """A block-quantized tensor. ``codes`` has the (zero-padded) original
    shape; ``exps`` replaces the quantized axis (last) by n_blocks.

    value[..., b*32 + i] = codes[..., b*32 + i] / 2 * 2^exps[..., b]
    """

    codes: jax.Array  # int8 in [-12, 12], shape [..., K_pad]
    exps: jax.Array  # int8 unbiased E8M0 exponent, shape [..., K_pad // 32]


def exp2i(e: jax.Array) -> jax.Array:
    """Exact 2^e (float32) for integer-valued ``e`` via exponent-field
    bit construction. ``jnp.exp2`` is only ~1-ulp accurate on CPU (it
    lowers to ``exp(x*ln2)``), which breaks bit-exactness; this is exact
    for e in [-252, 252] (split into two factors to cover beyond the
    single-factor [-126, 127] range)."""
    e = jnp.asarray(e, jnp.int32)
    h1 = jnp.clip(e // 2, -126, 127)
    h2 = jnp.clip(e - h1, -126, 127)

    def f(h):
        return jax.lax.bitcast_convert_type(
            ((h + 127) << 23).astype(jnp.int32), jnp.float32
        )

    return f(h1) * f(h2)


def _pad_last(x: jax.Array, multiple: int = BLOCK) -> jax.Array:
    k = x.shape[-1]
    rem = (-k) % multiple
    if rem:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
        x = jnp.pad(x, pad)
    return x


def quantize_e2m1(y: jax.Array) -> jax.Array:
    """Round ``y`` to the E2M1 grid (round-to-nearest-even), returning
    integer codes ``2 * fp4`` as int8. Input must already be scaled."""
    ay = jnp.abs(y)
    # piecewise grid step: 0.5 for |y|<2, 1 for [2,4), 2 for [4,6]
    e = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(ay, 2.0**-10))), 0, EMAX_ELEM)
    step = exp2i(e - 1)  # in units of value; code step = 2*step
    q = jnp.rint(ay / step) * step  # ties-to-even on the local grid
    q = jnp.minimum(q, FP4_MAX)
    code = jnp.sign(y) * (2.0 * q)
    return code.astype(jnp.int8)


def quantize(x: jax.Array, axis: int = -1) -> MX:
    """Block-quantize ``x`` to MXFP4 along ``axis`` (padded to 32)."""
    x = jnp.moveaxis(x, axis, -1) if axis not in (-1, x.ndim - 1) else x
    x = _pad_last(x.astype(jnp.float32))
    shp = x.shape
    xb = x.reshape(shp[:-1] + (shp[-1] // BLOCK, BLOCK))
    amax = jnp.max(jnp.abs(xb), axis=-1)
    # OCP MX: shared_exp = floor(log2(max)) - emax_elem; zero block -> emin
    e = jnp.floor(jnp.log2(jnp.where(amax > 0, amax, 1.0))) - EMAX_ELEM
    e = jnp.where(amax > 0, e, E8M0_MIN)
    e = jnp.clip(e, E8M0_MIN, E8M0_MAX)
    codes = quantize_e2m1(xb * exp2i(-e)[..., None])
    return MX(codes.reshape(shp), e.astype(jnp.int8))


def dequantize(mx: MX, out_len: int | None = None, dtype=jnp.float32) -> jax.Array:
    shp = mx.codes.shape
    cb = mx.codes.reshape(shp[:-1] + (shp[-1] // BLOCK, BLOCK))
    v = cb.astype(jnp.float32) * 0.5 * exp2i(mx.exps)[..., None]
    v = v.reshape(shp)
    if out_len is not None and out_len != shp[-1]:
        v = v[..., :out_len]
    return v.astype(dtype)


def encode_weight_unsigned(mx: MX) -> jax.Array:
    """INT5 affine map of weight codes into [0, 24] (uint8)."""
    return (mx.codes.astype(jnp.int16) + WEIGHT_BIAS).astype(jnp.uint8)


def decode_weight_unsigned(u: jax.Array) -> jax.Array:
    return (u.astype(jnp.int16) - WEIGHT_BIAS).astype(jnp.int8)


# ---------------------------------------------------------------- packing

def pack_codes(codes: jax.Array) -> jax.Array:
    """Pack int8 codes (2*fp4) into E2M1 nibbles, two per uint8.

    Nibble layout: [sign(1) | exp(2) | man(1)]; even element in low nibble.
    Last axis must be even (blocks of 32 always are).
    """
    sign = (codes < 0).astype(jnp.uint8)
    mag = jnp.abs(codes.astype(jnp.int32))
    nib = _ABS_CODE_TO_NIBBLE[mag] | (sign << 3)
    lo, hi = nib[..., 0::2], nib[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_codes(packed: jax.Array) -> jax.Array:
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    nib = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))
    mag = _NIBBLE_TO_CODE[(nib & 0x7).astype(jnp.int32)]
    sign = jnp.where((nib >> 3) & 1, -1, 1).astype(jnp.int8)
    return (sign * mag).astype(jnp.int8)


def exps_to_biased(exps: jax.Array) -> jax.Array:
    """Unbiased int8 exponent -> biased uint8 (E8M0 storage)."""
    return (exps.astype(jnp.int16) + 127).astype(jnp.uint8)


def exps_from_biased(b: jax.Array) -> jax.Array:
    return (b.astype(jnp.int16) - 127).astype(jnp.int8)


# ------------------------------------------------------------- fake quant

@jax.custom_vjp
def fake_quant(x: jax.Array) -> jax.Array:
    """Quantize-dequantize along the last axis with a straight-through
    estimator (QAT-style). Shape is preserved (pad/unpad internally)."""
    k = x.shape[-1]
    return dequantize(quantize(x), out_len=k, dtype=x.dtype)


def _fq_fwd(x):
    return fake_quant(x), None


def _fq_bwd(_, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_axis(x: jax.Array, axis: int) -> jax.Array:
    if axis in (-1, x.ndim - 1):
        return fake_quant(x)
    xm = jnp.moveaxis(x, axis, -1)
    return jnp.moveaxis(fake_quant(xm), -1, axis)


# ------------------------------------------------------------ bf16 helper

def to_bf16(x: jax.Array) -> jax.Array:
    return x.astype(jnp.bfloat16)


def mx_dot_bf16(a: MX, b: MX, bf16_partials: bool = False) -> jax.Array:
    """Digital-path dot product a @ b with MXFP4 operands and BF16-style
    accumulation semantics (paper §4.5).

    a: quantized along last axis, codes [..., K]; b: quantized along FIRST
    axis of a [K, N] weight (codes [K, N], exps [Kb, N] — produced by
    ``quantize(w.T).T``-style helpers below).

    With ``bf16_partials`` the per-32-block partial sums are rounded to
    BF16 before the cross-block accumulation (emulating the systolic
    array's BF16 accumulator at block granularity); otherwise f32
    accumulation with a final bf16 round (fast path).
    """
    va = dequantize(a)  # [..., K]
    vb = dequantize_w(b)  # [K, N]
    K = vb.shape[0]
    if bf16_partials:
        nb = K // BLOCK
        vab = va[..., :K].reshape(va.shape[:-1] + (nb, BLOCK))
        vbb = vb.reshape(nb, BLOCK, -1)
        parts = jnp.einsum("...bk,bkn->...bn", vab, vbb)
        parts = parts.astype(jnp.bfloat16).astype(jnp.float32)
        return jnp.sum(parts, axis=-2).astype(jnp.bfloat16)
    return jnp.matmul(va[..., :K], vb).astype(jnp.bfloat16)


class MXW(NamedTuple):
    """Weight matrix [K, N] quantized along K (contraction axis).

    codes: int8 [K_pad, N]; exps: int8 [K_pad//32, N].
    """

    codes: jax.Array
    exps: jax.Array


def quantize_w(w: jax.Array) -> MXW:
    """Quantize a [K, N] weight along K (axis 0)."""
    mx = quantize(w.T)  # blocks along K
    return MXW(jnp.swapaxes(mx.codes, -1, -2), jnp.swapaxes(mx.exps, -1, -2))


def dequantize_w(w: MXW, dtype=jnp.float32) -> jax.Array:
    mx = MX(jnp.swapaxes(w.codes, -1, -2), jnp.swapaxes(w.exps, -1, -2))
    return jnp.swapaxes(dequantize(mx, dtype=dtype), -1, -2)
