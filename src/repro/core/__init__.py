# The paper's primary contribution: MXFP4 microscaling numerics, the
# analog CTT-CIM datapath simulation, and the digital MXFP4 attention
# path. Sibling subpackages provide the framework substrates.
from repro.core import cim, digital, mx  # noqa: F401
