"""Compatibility shim: ``sqnr_db`` lives in ``repro.obs.fidelity``, the
numerical-fidelity observability module (per-layer SQNR tracing, MXFP4 /
ADC health probes, calibration-drift detection); import from
``repro.obs`` in new code."""

from __future__ import annotations

from repro.obs.fidelity import sqnr_db  # noqa: F401
