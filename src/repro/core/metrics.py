"""Compatibility shim: ``sqnr_db`` moved to ``repro.obs.fidelity``
(the telemetry namespace); import from ``repro.obs`` in new code."""

from __future__ import annotations

from repro.obs.fidelity import sqnr_db  # noqa: F401
