"""Analog CTT-CIM datapath simulation (paper §3, §5.2.2).

Models a CIM linear layer ``y = x @ w`` where:

- ``w`` is MXFP4-quantized along K and resident in the array as INT5 codes,
- ``x`` is MXFP4-quantized per (row, 32-block) and streamed as bit-planes
  (bit-serial streaming is numerically exact — see tests — so we compute in
  the signed integer code domain directly),
- each block's integer partial sum ``S = sum_i cx_i * cw_i`` carries scale
  ``2^(E_X + E_W) / 4``; contributions are aligned to a target exponent
  ``E_N`` through current mirrors with a limited shift budget of ``CM``
  bits.  Blocks with exponent in ``[E_N - CM, E_N]`` are exact, blocks
  below **underflow to zero**, blocks above are shift-clamped (overflow
  "diminishes high-magnitude activations", §3.2.1),
- the optional second pass recomputes underflowed blocks at
  ``E_N2 = E_N - CM`` and merges (Row-Hist 2-Pass),
- an n-bit SAR ADC uniformly quantizes each (pass, column) sum with a
  per-layer calibrated full scale.

Exponent-target strategies (Fig 5): offline ``row_hist`` (per-layer E_N =
max observed block exponent, eliminating overflow) and online ``row0`` /
``row_opt`` baselines.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.core.mx import BLOCK, MX, MXW


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    adc_bits: int | None = 10  # None disables the ADC model (Fig 5 style)
    cm_bits: int = 3
    two_pass: bool = True
    strategy: str = "row_hist"  # row_hist | row0 | row_opt
    strategy_offset: int = 0  # constant E_N offset for online strategies
    collect_stats: bool = False


class LayerCalib(NamedTuple):
    e_n: jax.Array  # [] int32 per-layer target exponent
    adc_fs: jax.Array  # [] f32 ADC full scale (aligned-integer units)


def _block_partials(x: jax.Array, w: MXW):
    """Quantize activations and form per-block integer partial sums.

    Returns (S, es) where S[..., b, m] is the exact int partial sum (f32
    carrier, |S| <= 32*144 so exact) and es[..., b, m] = E_X + E_W.
    """
    k = w.codes.shape[0]
    xq = mxlib.quantize(x[..., :k])
    nb = xq.codes.shape[-1] // BLOCK
    cx = xq.codes.reshape(xq.codes.shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    cw = w.codes.reshape(nb, BLOCK, -1).astype(jnp.float32)
    s = jnp.einsum("...bk,bkm->...bm", cx, cw)
    es = xq.exps[..., :, None].astype(jnp.int32) + w.exps.astype(jnp.int32)
    return s, es


def _adc(c: jax.Array, fs: jax.Array, bits: int | None) -> jax.Array:
    if bits is None:
        return c
    half = 2.0 ** (bits - 1)
    delta = fs / half
    q = jnp.clip(jnp.round(c / delta), -half, half - 1.0)
    return q * delta


def _target_exponent(cfg: CIMConfig, calib: LayerCalib | None, es: jax.Array):
    if cfg.strategy == "row_hist":
        assert calib is not None, "row_hist needs offline calibration"
        return calib.e_n
    if cfg.strategy == "row0":
        # first block-row's exponent reused for all rows (per column)
        return es[..., 0:1, :] + cfg.strategy_offset
    if cfg.strategy == "row_opt":
        # per-column median shared exponent
        return (
            jnp.median(es, axis=-2, keepdims=True).astype(jnp.int32)
            + cfg.strategy_offset
        )
    raise ValueError(f"unknown strategy {cfg.strategy!r}")


def _en_scale(e_n, delta: int = 0) -> jax.Array:
    """2^(E_N - delta) broadcastable to [..., M] (squeezes the block axis
    online strategies carry)."""
    sc = mxlib.exp2i(jnp.asarray(e_n, jnp.int32) - delta)
    if sc.ndim > 0:
        sc = sc[..., 0, :]
    return sc


def cim_linear(
    x: jax.Array,
    w: MXW,
    cfg: CIMConfig,
    calib: LayerCalib | None = None,
):
    """Analog CIM forward. Returns (y[..., M] float32, stats dict)."""
    s, es = _block_partials(x, w)
    e_n = _target_exponent(cfg, calib, es)
    sh = es - e_n  # required shift; exact iff -CM <= sh <= 0
    cm = cfg.cm_bits

    over = sh > 0
    under1 = sh < -cm
    a1 = jnp.where(
        under1, 0.0, s * mxlib.exp2i(jnp.clip(sh, -cm, 0))
    )
    c1 = jnp.sum(a1, axis=-2)  # [..., M] in units of 2^{E_N}/4

    fs = calib.adc_fs if calib is not None else jnp.float32(0.0)
    c1q = _adc(c1, fs, cfg.adc_bits)
    y = c1q * _en_scale(e_n) * 0.25

    under2 = jnp.zeros_like(under1)
    if cfg.two_pass:
        sh2 = sh + cm  # pass-2 target E_N2 = E_N - CM
        under2 = sh2 < -cm
        a2 = jnp.where(
            under1 & ~under2,
            s * mxlib.exp2i(jnp.clip(sh2, -cm, 0)),
            0.0,
        )
        c2 = jnp.sum(a2, axis=-2)
        c2q = _adc(c2, fs, cfg.adc_bits)
        y = y + c2q * _en_scale(e_n, cm) * 0.25

    stats = {}
    if cfg.collect_stats:
        nz = jnp.abs(s) > 0  # only blocks with nonzero partials matter
        tot = jnp.maximum(jnp.sum(nz), 1)
        stats = {
            "overflow_rate": jnp.sum(over & nz) / tot,
            "underflow_rate_p1": jnp.sum(under1 & nz) / tot,
            "underflow_rate_p2": jnp.sum((under1 & under2) & nz) / tot,
        }
    return y.astype(jnp.float32), stats


# ------------------------------------------------------------ calibration

def calibrate_rowhist(
    batches, w: MXW, cfg: CIMConfig, percentile: float = 100.0
) -> LayerCalib:
    """Offline Row-Hist calibration (paper §3.2.1): pick the per-layer
    target exponent from the distribution of block output exponents over
    representative batches (prioritising zero overflow => max), then
    calibrate the ADC full scale at that E_N.
    """
    e_n = None
    for xb in batches:
        s, es = _block_partials(xb, w)
        live = jnp.abs(s) > 0
        cand = jnp.where(live, es, -(10**6))
        if percentile >= 100.0:
            m = jnp.max(cand)
        else:
            m = jnp.percentile(jnp.where(live, es, jnp.nan), percentile)
            m = jnp.asarray(jnp.ceil(m), jnp.int32)
        e_n = m if e_n is None else jnp.maximum(e_n, m)
    e_n = jnp.asarray(e_n, jnp.int32)

    fs = jnp.float32(0.0)
    cm = cfg.cm_bits
    for xb in batches:
        s, es = _block_partials(xb, w)
        sh = es - e_n
        a1 = jnp.where(sh < -cm, 0.0, s * mxlib.exp2i(jnp.clip(sh, -cm, 0)))
        fs = jnp.maximum(fs, jnp.max(jnp.abs(jnp.sum(a1, axis=-2))))
        if cfg.two_pass:
            sh2 = sh + cm
            a2 = jnp.where(
                (sh < -cm) & (sh2 >= -cm),
                s * mxlib.exp2i(jnp.clip(sh2, -cm, 0)),
                0.0,
            )
            fs = jnp.maximum(fs, jnp.max(jnp.abs(jnp.sum(a2, axis=-2))))
    return LayerCalib(e_n=e_n, adc_fs=fs)


# ------------------------------------------------- bias-column equivalence

def cim_linear_unsigned(x: jax.Array, w: MXW, cfg: CIMConfig, calib: LayerCalib):
    """Hardware-faithful variant: weights stored as *unsigned* [0, 24]
    codes (w + 12); the bias term ``12 * sum_i x_i`` is produced by an
    identical bias column per block and subtracted per output channel with
    the same per-block alignment (paper eq. (2)). Numerically identical to
    :func:`cim_linear` up to the shared ADC — used by tests to prove the
    affine encoding + bias-column scheme is exact."""
    k = w.codes.shape[0]
    xq = mxlib.quantize(x[..., :k])
    nb = xq.codes.shape[-1] // BLOCK
    cx = xq.codes.reshape(xq.codes.shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    wu = (w.codes.astype(jnp.int16) + mxlib.WEIGHT_BIAS).astype(jnp.float32)
    cwu = wu.reshape(nb, BLOCK, -1)
    s_u = jnp.einsum("...bk,bkm->...bm", cx, cwu)  # unsigned-weight partials
    bias = jnp.sum(cx, axis=-1)[..., None] * float(mxlib.WEIGHT_BIAS)  # [...,b,1]
    s = s_u - bias  # per-block, pre-alignment subtraction of the bias column
    es = xq.exps[..., :, None].astype(jnp.int32) + w.exps.astype(jnp.int32)

    e_n = _target_exponent(cfg, calib, es)
    cm = cfg.cm_bits
    sh = es - e_n
    a1 = jnp.where(sh < -cm, 0.0, s * mxlib.exp2i(jnp.clip(sh, -cm, 0)))
    c1q = _adc(jnp.sum(a1, axis=-2), calib.adc_fs, cfg.adc_bits)
    y = c1q * _en_scale(e_n) * 0.25
    if cfg.two_pass:
        sh2 = sh + cm
        a2 = jnp.where(
            (sh < -cm) & (sh2 >= -cm),
            s * mxlib.exp2i(jnp.clip(sh2, -cm, 0)),
            0.0,
        )
        c2q = _adc(jnp.sum(a2, axis=-2), calib.adc_fs, cfg.adc_bits)
        y = y + c2q * _en_scale(e_n, cm) * 0.25
    return y.astype(jnp.float32)


# --------------------------------------------------- bit-plane decomposition

def bitplane_dot(cx: jax.Array, cw: jax.Array) -> jax.Array:
    """Bit-serial evaluation of sum_i cx_i*cw_i with cx in [-12,12] streamed
    as 5-bit two's-complement planes (paper eq. (1)); exactness is tested
    against the direct integer dot."""
    xi = cx.astype(jnp.int32) & 0x1F  # 5-bit two's complement
    planes = [(xi >> j) & 1 for j in range(5)]
    weights = [1, 2, 4, 8, -16]
    t = [
        jnp.sum(p.astype(jnp.float32) * cw.astype(jnp.float32), axis=-1)
        for p in planes
    ]
    return sum(wj * tj for wj, tj in zip(weights, t))
