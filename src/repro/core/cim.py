"""Analog CTT-CIM datapath simulation (paper §3, §5.2.2).

Models a CIM linear layer ``y = x @ w`` where:

- ``w`` is MXFP4-quantized along K and resident in the array as INT5 codes,
- ``x`` is MXFP4-quantized per (row, 32-block) and streamed as bit-planes
  (bit-serial streaming is numerically exact — see tests — so we compute in
  the signed integer code domain directly),
- each block's integer partial sum ``S = sum_i cx_i * cw_i`` carries scale
  ``2^(E_X + E_W) / 4``; contributions are aligned to a target exponent
  ``E_N`` through current mirrors with a limited shift budget of ``CM``
  bits.  Blocks with exponent in ``[E_N - CM, E_N]`` are exact, blocks
  below **underflow to zero**, blocks above are shift-clamped (overflow
  "diminishes high-magnitude activations", §3.2.1),
- the optional second pass recomputes underflowed blocks at
  ``E_N2 = E_N - CM`` and merges (Row-Hist 2-Pass),
- an n-bit SAR ADC uniformly quantizes each (pass, column) sum with a
  per-layer calibrated full scale.

Exponent-target strategies (Fig 5): offline ``row_hist`` (per-layer E_N =
max observed block exponent, eliminating overflow) and online ``row0`` /
``row_opt`` baselines.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.core.mx import BLOCK, MX, MXW


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    adc_bits: int | None = 10  # None disables the ADC model (Fig 5 style)
    cm_bits: int = 3
    two_pass: bool = True
    strategy: str = "row_hist"  # row_hist | row0 | row_opt
    strategy_offset: int = 0  # constant E_N offset for online strategies
    collect_stats: bool = False


class LayerCalib(NamedTuple):
    e_n: jax.Array  # [] int32 per-layer target exponent
    adc_fs: jax.Array  # [] f32 ADC full scale (aligned-integer units)


def _block_partials(x: jax.Array, w: MXW):
    """Quantize activations and form per-block integer partial sums.

    Returns (S, es) where S[..., b, m] is the exact int partial sum (f32
    carrier, |S| <= 32*144 so exact) and es[..., b, m] = E_X + E_W.
    """
    k = w.codes.shape[0]
    xq = mxlib.quantize(x[..., :k])
    nb = xq.codes.shape[-1] // BLOCK
    cx = xq.codes.reshape(xq.codes.shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    cw = w.codes.reshape(nb, BLOCK, -1).astype(jnp.float32)
    s = jnp.einsum("...bk,bkm->...bm", cx, cw)
    es = xq.exps[..., :, None].astype(jnp.int32) + w.exps.astype(jnp.int32)
    return s, es


def _adc(c: jax.Array, fs: jax.Array, bits: int | None) -> jax.Array:
    if bits is None:
        return c
    half = 2.0 ** (bits - 1)
    delta = fs / half
    q = jnp.clip(jnp.round(c / delta), -half, half - 1.0)
    return q * delta


def _target_exponent(cfg: CIMConfig, calib: LayerCalib | None, es: jax.Array):
    if cfg.strategy == "row_hist":
        assert calib is not None, "row_hist needs offline calibration"
        return calib.e_n
    if cfg.strategy == "row0":
        # first block-row's exponent reused for all rows (per column)
        return es[..., 0:1, :] + cfg.strategy_offset
    if cfg.strategy == "row_opt":
        # per-column median shared exponent
        return (
            jnp.median(es, axis=-2, keepdims=True).astype(jnp.int32)
            + cfg.strategy_offset
        )
    raise ValueError(f"unknown strategy {cfg.strategy!r}")


def _en_scale(e_n, delta: int = 0) -> jax.Array:
    """2^(E_N - delta) broadcastable to [..., M] (squeezes the block axis
    online strategies carry)."""
    sc = mxlib.exp2i(jnp.asarray(e_n, jnp.int32) - delta)
    if sc.ndim > 0:
        sc = sc[..., 0, :]
    return sc


# ------------------------------------------------- blockwise (scan) core

def _xq_blocks(x: jax.Array, k: int):
    """Quantize activations straight to the code domain and expose the
    32-block structure: (cx [..., nb, 32] f32 codes, ex [..., nb] int32)."""
    xq = mxlib.quantize(x[..., :k])
    nb = xq.codes.shape[-1] // BLOCK
    cx = xq.codes.reshape(xq.codes.shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    return cx, xq.exps.astype(jnp.int32), nb


def _scan_blocks(cx, ex, w: MXW, e_n, cfg: CIMConfig):
    """``lax.scan`` over the 32-blocks: each step forms one block's exact
    integer partial [..., N], aligns it to the scalar Row-Hist target
    ``e_n`` under the CM window, and accumulates into the running
    (pass-1, pass-2) sums — O(N) live memory instead of the O(nb * N)
    block-partial materialization, and the same sequential block order as
    the Pallas kernel's ``fori_loop``.

    Returns (c1 [..., N], c2 [..., N], counts int32 [4] =
    (overflow, underflow_p1, underflow_p2, live-blocks), counts all zero
    unless ``cfg.collect_stats``).
    """
    cm = cfg.cm_bits
    nb = cx.shape[-2]
    wc = w.codes.astype(jnp.float32).reshape(nb, BLOCK, -1)
    e_n = jnp.asarray(e_n, jnp.int32)
    # The alignment runs in the *linear* domain: with uv = 2^(E_X - E_N)
    # * 2^(E_W) — a product of exact powers of two, so bit-exact —
    #   2^clip(sh, -cm, 0) * [sh >= -cm]  ==  where(uv < 2^-cm, 0, min(uv, 1))
    # because 2^x is monotone. Three elementwise ops per block-pass instead
    # of the integer clip/shift chain; the selected scales are bitwise the
    # same powers of two.
    u = mxlib.exp2i(ex - e_n)  # [..., nb]
    v = mxlib.exp2i(w.exps.astype(jnp.int32))  # [nb, N] (static per call)
    lo = 2.0 ** -cm
    lo2 = 2.0 ** -(2 * cm)

    def block(carry, cxb, ub, wcb, vb):
        c1, c2, cnt = carry
        s = jnp.einsum(
            "...k,kn->...n", cxb, wcb, preferred_element_type=jnp.float32
        )  # exact: |S| <= 32*144, f32 accumulation
        uv = ub[..., None] * vb  # 2^sh, exact
        under1 = uv < lo
        c1 = c1 + s * jnp.where(under1, 0.0, jnp.minimum(uv, 1.0))
        if cfg.two_pass:
            # pass-2 target E_N2 = E_N - CM: window sh in [-2cm, -cm)
            c2 = c2 + s * jnp.where(
                under1 & (uv >= lo2), uv * (2.0 ** cm), 0.0
            )
        if cfg.collect_stats:
            nz = jnp.abs(s) > 0  # only blocks with nonzero partials matter
            # pass-2 underflow only exists when a second pass runs (the
            # materialized reference reports 0.0 for single-pass configs)
            under12 = (uv < lo2) & nz if cfg.two_pass else jnp.zeros_like(nz)
            cnt = cnt + jnp.stack([
                jnp.sum((uv > 1.0) & nz, dtype=jnp.int32),
                jnp.sum(under1 & nz, dtype=jnp.int32),
                jnp.sum(under12, dtype=jnp.int32),
                jnp.sum(nz, dtype=jnp.int32),
            ])
        return c1, c2, cnt

    zero = jnp.zeros(cx.shape[:-2] + (wc.shape[-1],), jnp.float32)
    carry = (zero, zero, jnp.zeros((4,), jnp.int32))
    if nb <= 8:
        # hidden-size block counts: a flat Python loop over direct slices
        # (no moveaxis transposes, no scan carry plumbing) compiles to the
        # leanest graph; the accumulation order is identical to the scan
        for b in range(nb):
            carry = block(carry, cx[..., b, :], u[..., b], wc[b], v[b])
        return carry
    cxs = jnp.moveaxis(cx, -2, 0)  # [nb, ..., 32]
    us = jnp.moveaxis(u, -1, 0)  # [nb, ...]
    (c1, c2, cnt), _ = jax.lax.scan(
        lambda c, xs: (block(c, *xs), None), carry, (cxs, us, wc, v),
        unroll=8,
    )
    return c1, c2, cnt


def cim_linear(
    x: jax.Array,
    w: MXW,
    cfg: CIMConfig,
    calib: LayerCalib | None = None,
):
    """Analog CIM forward. Returns (y[..., M] float32, stats dict).

    The offline-calibrated ``row_hist`` strategy (the serving hot path)
    runs the blockwise scan core; the online ``row0``/``row_opt``
    baselines need the full block-exponent field and keep the materialized
    reference composition.
    """
    if cfg.strategy == "row_hist":
        assert calib is not None, "row_hist needs offline calibration"
        cx, ex, _ = _xq_blocks(x, w.codes.shape[0])
        c1, c2, cnt = _scan_blocks(cx, ex, w, calib.e_n, cfg)
        y = _adc(c1, calib.adc_fs, cfg.adc_bits) * _en_scale(calib.e_n) * 0.25
        if cfg.two_pass:
            y = y + (
                _adc(c2, calib.adc_fs, cfg.adc_bits)
                * _en_scale(calib.e_n, cfg.cm_bits) * 0.25
            )
        stats = {}
        if cfg.collect_stats:
            tot = jnp.maximum(cnt[3], 1)
            stats = {
                "overflow_rate": cnt[0] / tot,
                "underflow_rate_p1": cnt[1] / tot,
                "underflow_rate_p2": cnt[2] / tot,
            }
        return y.astype(jnp.float32), stats
    return _cim_linear_materialized(x, w, cfg, calib)


def _cim_linear_materialized(
    x: jax.Array,
    w: MXW,
    cfg: CIMConfig,
    calib: LayerCalib | None = None,
):
    """Reference composition over the materialized [..., nb, N] block
    partials (needed by the online strategies, whose target exponent is a
    function of the whole exponent field)."""
    s, es = _block_partials(x, w)
    e_n = _target_exponent(cfg, calib, es)
    sh = es - e_n  # required shift; exact iff -CM <= sh <= 0
    cm = cfg.cm_bits

    over = sh > 0
    under1 = sh < -cm
    a1 = jnp.where(
        under1, 0.0, s * mxlib.exp2i(jnp.clip(sh, -cm, 0))
    )
    c1 = jnp.sum(a1, axis=-2)  # [..., M] in units of 2^{E_N}/4

    fs = calib.adc_fs if calib is not None else jnp.float32(0.0)
    c1q = _adc(c1, fs, cfg.adc_bits)
    y = c1q * _en_scale(e_n) * 0.25

    under2 = jnp.zeros_like(under1)
    if cfg.two_pass:
        sh2 = sh + cm  # pass-2 target E_N2 = E_N - CM
        under2 = sh2 < -cm
        a2 = jnp.where(
            under1 & ~under2,
            s * mxlib.exp2i(jnp.clip(sh2, -cm, 0)),
            0.0,
        )
        c2 = jnp.sum(a2, axis=-2)
        c2q = _adc(c2, fs, cfg.adc_bits)
        y = y + c2q * _en_scale(e_n, cm) * 0.25

    stats = {}
    if cfg.collect_stats:
        nz = jnp.abs(s) > 0  # only blocks with nonzero partials matter
        tot = jnp.maximum(jnp.sum(nz), 1)
        stats = {
            "overflow_rate": jnp.sum(over & nz) / tot,
            "underflow_rate_p1": jnp.sum(under1 & nz) / tot,
            "underflow_rate_p2": jnp.sum((under1 & under2) & nz) / tot,
        }
    return y.astype(jnp.float32), stats


# ------------------------------------------------------------ calibration

@jax.jit
def _calib_max_exponent(x: jax.Array, w: MXW) -> jax.Array:
    """Max live block-output exponent over one batch, blockwise (O(N)
    live memory, jitted — the calibration capture runs eagerly, so each
    per-batch pass compiles once per activation shape)."""
    cx, ex, nb = _xq_blocks(x, w.codes.shape[0])
    wc = w.codes.astype(jnp.float32).reshape(nb, BLOCK, -1)
    we = w.exps.astype(jnp.int32)

    def body(m, xs):
        cxb, exb, wcb, web = xs
        s = jnp.einsum(
            "...k,kn->...n", cxb, wcb, preferred_element_type=jnp.float32
        )
        es = exb[..., None] + web
        cand = jnp.where(jnp.abs(s) > 0, es, -(10**6))
        return jnp.maximum(m, jnp.max(cand)), None

    m, _ = jax.lax.scan(
        body, jnp.int32(-(10**6)),
        (jnp.moveaxis(cx, -2, 0), jnp.moveaxis(ex, -1, 0), wc, we),
    )
    return m


@functools.partial(jax.jit, static_argnames=("cfg",))
def _calib_full_scale(x: jax.Array, w: MXW, e_n, cfg: CIMConfig):
    """Max |per-pass column sum| over one batch at target ``e_n`` —
    the same blockwise accumulation as the forward, so the calibrated
    full scale covers exactly what the forward's ADC sees."""
    cx, ex, _ = _xq_blocks(x, w.codes.shape[0])
    c1, c2, _ = _scan_blocks(
        cx, ex, w, e_n, dataclasses.replace(cfg, collect_stats=False)
    )
    fs = jnp.max(jnp.abs(c1))
    if cfg.two_pass:
        fs = jnp.maximum(fs, jnp.max(jnp.abs(c2)))
    return fs


def calibrate_rowhist(
    batches, w: MXW, cfg: CIMConfig, percentile: float = 100.0
) -> LayerCalib:
    """Offline Row-Hist calibration (paper §3.2.1): pick the per-layer
    target exponent from the distribution of block output exponents over
    representative batches (prioritising zero overflow => max), then
    calibrate the ADC full scale at that E_N. Both passes run jitted and
    blockwise; the sub-100 percentile variant needs the full exponent
    histogram and keeps the materialized path.
    """
    e_n = None
    for xb in batches:
        if percentile >= 100.0:
            m = _calib_max_exponent(xb, w)
        else:
            s, es = _block_partials(xb, w)
            live = jnp.abs(s) > 0
            m = jnp.percentile(jnp.where(live, es, jnp.nan), percentile)
            m = jnp.asarray(jnp.ceil(m), jnp.int32)
        e_n = m if e_n is None else jnp.maximum(e_n, m)
    e_n = jnp.asarray(e_n, jnp.int32)

    fs = jnp.float32(0.0)
    for xb in batches:
        fs = jnp.maximum(fs, _calib_full_scale(xb, w, e_n, cfg))
    return LayerCalib(e_n=e_n, adc_fs=fs)


# ------------------------------------------------- fidelity observability

def adc_health(c: jax.Array, fs, bits: int | None, code_buckets: tuple = ()):
    """ADC occupancy stats for one pass's pre-ADC column sums ``c``
    (aligned-integer units): how much of the n-bit code range traffic
    actually uses, and how often it runs off the end.

    - ``saturated``: samples whose ideal code ``round(c/delta)`` falls
      outside ``[-half, half]`` — i.e. |c| genuinely beyond full scale.
      A sample at exactly +fs rounds to ``half`` and is clipped one LSB
      by :func:`_adc` (the two's-complement asymmetric endpoint); that
      is quantization error, not saturation, and counting it would make
      every Row-Hist-calibrated layer (full scale == batch max) read as
      saturating on its own calibration data;
    - ``occ_*``: |clipped code| / half in [0, 1], bucketed on
      ``code_buckets`` for ``Histogram.merge_counts`` (plus sum/min/max);
    - ``peak``: max |c| — compare against the calibrated full scale for
      headroom.
    """
    peak = jnp.max(jnp.abs(c))
    n = c.size  # static under jit
    if bits is None:  # ADC model disabled: nothing saturates, no codes
        z = jnp.int32(0)
        return {
            "total": n, "saturated": z, "peak": peak,
            "occ_counts": jnp.zeros((len(code_buckets) + 1,), jnp.int32),
            "occ_sum": jnp.float32(0.0), "occ_n": 0,
            "occ_min": jnp.float32(0.0), "occ_max": jnp.float32(0.0),
        }
    half = 2.0 ** (bits - 1)
    raw = jnp.round(c / (fs / half))
    occ = jnp.abs(jnp.clip(raw, -half, half - 1.0)) / half
    return {
        "total": n,
        "saturated": jnp.sum((raw < -half) | (raw > half)),
        "peak": peak,
        "occ_counts": mxlib.bucket_counts(occ, code_buckets),
        "occ_sum": jnp.sum(occ),
        "occ_n": n,
        "occ_min": jnp.min(occ),
        "occ_max": jnp.max(occ),
    }


@functools.partial(jax.jit, static_argnames=("cfg", "code_buckets"))
def cim_linear_fidelity(
    x: jax.Array,
    w: MXW,
    cfg: CIMConfig,
    calib: LayerCalib,
    code_buckets: tuple = (),
):
    """Instrumented Row-Hist forward: ``y`` is bitwise :func:`cim_linear`
    (same ``_xq_blocks`` / ``_scan_blocks`` / ``_adc`` composition —
    ``collect_stats`` only adds the count accumulator), plus the health
    stats the fidelity probe publishes:

    - ``counts``: int32 [4] (overflow, underflow_p1, underflow_p2,
      live blocks) from the CM alignment window;
    - ``pass1`` / ``pass2``: :func:`adc_health` per ADC pass;
    - ``live_fs``: max |column sum| across passes — the quantity Row-Hist
      calibration maximises, so ``live_fs > calib.adc_fs`` means traffic
      has drifted beyond the calibration set;
    - ``live_e_max``: max live block-output exponent (vs ``calib.e_n``).

    Only the offline-calibrated ``row_hist`` strategy (the serving hot
    path) is supported.
    """
    assert cfg.strategy == "row_hist" and calib is not None
    cx, ex, _ = _xq_blocks(x, w.codes.shape[0])
    c1, c2, cnt = _scan_blocks(
        cx, ex, w, calib.e_n, dataclasses.replace(cfg, collect_stats=True)
    )
    y = _adc(c1, calib.adc_fs, cfg.adc_bits) * _en_scale(calib.e_n) * 0.25
    if cfg.two_pass:
        y = y + (
            _adc(c2, calib.adc_fs, cfg.adc_bits)
            * _en_scale(calib.e_n, cfg.cm_bits) * 0.25
        )
    h1 = adc_health(c1, calib.adc_fs, cfg.adc_bits, code_buckets)
    stats = {"counts": cnt, "pass1": h1, "live_fs": h1["peak"],
             "live_e_max": _calib_max_exponent(x, w)}
    if cfg.two_pass:
        h2 = adc_health(c2, calib.adc_fs, cfg.adc_bits, code_buckets)
        stats["pass2"] = h2
        stats["live_fs"] = jnp.maximum(h1["peak"], h2["peak"])
    return y.astype(jnp.float32), stats


# ------------------------------------------------- bias-column equivalence

def cim_linear_unsigned(x: jax.Array, w: MXW, cfg: CIMConfig, calib: LayerCalib):
    """Hardware-faithful variant: weights stored as *unsigned* [0, 24]
    codes (w + 12); the bias term ``12 * sum_i x_i`` is produced by an
    identical bias column per block and subtracted per output channel with
    the same per-block alignment (paper eq. (2)). Numerically identical to
    :func:`cim_linear` up to the shared ADC — used by tests to prove the
    affine encoding + bias-column scheme is exact."""
    k = w.codes.shape[0]
    xq = mxlib.quantize(x[..., :k])
    nb = xq.codes.shape[-1] // BLOCK
    cx = xq.codes.reshape(xq.codes.shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    wu = (w.codes.astype(jnp.int16) + mxlib.WEIGHT_BIAS).astype(jnp.float32)
    cwu = wu.reshape(nb, BLOCK, -1)
    s_u = jnp.einsum("...bk,bkm->...bm", cx, cwu)  # unsigned-weight partials
    bias = jnp.sum(cx, axis=-1)[..., None] * float(mxlib.WEIGHT_BIAS)  # [...,b,1]
    s = s_u - bias  # per-block, pre-alignment subtraction of the bias column
    es = xq.exps[..., :, None].astype(jnp.int32) + w.exps.astype(jnp.int32)

    e_n = _target_exponent(cfg, calib, es)
    cm = cfg.cm_bits
    sh = es - e_n
    a1 = jnp.where(sh < -cm, 0.0, s * mxlib.exp2i(jnp.clip(sh, -cm, 0)))
    c1q = _adc(jnp.sum(a1, axis=-2), calib.adc_fs, cfg.adc_bits)
    y = c1q * _en_scale(e_n) * 0.25
    if cfg.two_pass:
        sh2 = sh + cm
        a2 = jnp.where(
            (sh < -cm) & (sh2 >= -cm),
            s * mxlib.exp2i(jnp.clip(sh2, -cm, 0)),
            0.0,
        )
        c2q = _adc(jnp.sum(a2, axis=-2), calib.adc_fs, cfg.adc_bits)
        y = y + c2q * _en_scale(e_n, cm) * 0.25
    return y.astype(jnp.float32)


# --------------------------------------------------- bit-plane decomposition

def bitplane_dot(cx: jax.Array, cw: jax.Array) -> jax.Array:
    """Bit-serial evaluation of sum_i cx_i*cw_i with cx in [-12,12] streamed
    as 5-bit two's-complement planes (paper eq. (1)); exactness is tested
    against the direct integer dot."""
    xi = cx.astype(jnp.int32) & 0x1F  # 5-bit two's complement
    planes = [(xi >> j) & 1 for j in range(5)]
    weights = [1, 2, 4, 8, -16]
    t = [
        jnp.sum(p.astype(jnp.float32) * cw.astype(jnp.float32), axis=-1)
        for p in planes
    ]
    return sum(wj * tj for wj, tj in zip(weights, t))
