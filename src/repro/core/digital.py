"""Digital-stage numerics (paper §4.4–4.5): MXFP4 systolic attention with
BF16 accumulation and a FlashAttention-style deferred softmax.

This is the *numerics simulator* used for fidelity experiments; the
production attention path is the Pallas flash-attention kernel in
``repro.kernels.flash_attention``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib


def mx_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = False,
    tile: int = 64,
    quantize_sv: bool = True,
) -> jax.Array:
    """Scaled dot-product attention on the paper's digital datapath.

    q, k, v: [..., S, D] (already per-head). Q and K are MXFP4-quantized
    row-major along D (the QK^T contraction); softmax runs in BF16 with a
    FlashAttention-style running max/sum over key tiles (deferred final
    division); the probability tiles and V (column-wise along S, i.e. the
    SV contraction) are re-quantized to MXFP4 before the SV systolic array.
    """
    dk = q.shape[-1]
    qq = mxlib.fake_quant(q.astype(jnp.float32))
    kq = mxlib.fake_quant(k.astype(jnp.float32))
    s = jnp.einsum("...qd,...kd->...qk", qq, kq).astype(jnp.bfloat16)
    s = (s.astype(jnp.float32) * (dk**-0.5)).astype(jnp.bfloat16)

    sl = s.shape[-1]
    if causal:
        ii = jnp.arange(s.shape[-2])[:, None]
        jj = jnp.arange(sl)[None, :]
        s = jnp.where(jj <= ii, s, jnp.bfloat16(-jnp.inf))

    # FlashAttention-style streaming softmax over key tiles of ``tile``.
    pad = (-sl) % tile
    if pad:
        s = jnp.pad(s, [(0, 0)] * (s.ndim - 1) + [(0, pad)],
                    constant_values=-jnp.inf)
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad), (0, 0)])
    nt = s.shape[-1] // tile
    st = s.reshape(s.shape[:-1] + (nt, tile)).astype(jnp.float32)
    vt = v.reshape(v.shape[:-2] + (nt, tile, v.shape[-1])).astype(jnp.float32)

    m = jnp.full(st.shape[:-2], -jnp.inf, jnp.float32)
    acc = jnp.zeros(st.shape[:-2] + (v.shape[-1],), jnp.float32)
    den = jnp.zeros(st.shape[:-2], jnp.float32)
    for t in range(nt):
        sc = st[..., t, :]
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(jnp.isfinite(sc), p, 0.0)
        if quantize_sv:
            p = mxlib.fake_quant(p)
            vtile = mxlib.fake_quant_axis(vt[..., t, :, :], axis=-2)
        else:
            vtile = vt[..., t, :, :]
        pv = jnp.einsum("...qk,...kd->...qd", p, vtile)
        acc = acc * corr[..., None] + pv
        den = den * corr + jnp.sum(p, axis=-1)
        m = m_new
    den = jnp.where(den == 0.0, 1.0, den)
    out = acc / den[..., None]  # deferred division (normalizer block)
    return out.astype(jnp.bfloat16)


def attention_ref(q, k, v, causal: bool = False) -> jax.Array:
    """Full-precision oracle."""
    dk = q.shape[-1]
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * dk**-0.5
    if causal:
        ii = jnp.arange(s.shape[-2])[:, None]
        jj = jnp.arange(s.shape[-1])[None, :]
        s = jnp.where(jj <= ii, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32))
