"""Pattern-based LM family covering all assigned architectures.

A model is a sequence of *segments*: maximal runs of identical block kinds
(attention+FFN/MoE, Mamba2, mLSTM, sLSTM, Zamba shared block). Runs with
n > 1 keep their parameters stacked along a leading layer axis and execute
under ``jax.lax.scan`` (compact HLO => tractable 512-device SPMD compiles
even for 94-layer MoE models), optionally rematerialized.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_mod
from repro.layers import ffn as ffn_mod
from repro.layers import rope as ropelib
from repro.layers import moe as moe_mod
from repro.layers import ssm as ssm_mod
from repro.layers import xlstm as xl_mod
from repro.layers.common import (
    RunCtx,
    embed_init,
    linear_apply,
    linear_init,
    norm_apply,
    norm_init,
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | audio | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    # attention
    attn_pattern: str = "full"  # full | swa | local_global
    window: int = 4096
    lg_ratio: int = 5  # N local per 1 global
    rope_theta: float = 1e4
    rope_theta_global: float = 1e6
    mrope: bool = False
    causal: bool = True
    qk_norm: bool = False
    use_bias: bool = False
    # ffn
    ffn_kind: str = "swiglu"
    norm: str = "rmsnorm"
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_shard: str = "ep"  # ep | tp (drives sharding rules)
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    shared_attn_every: int = 0  # zamba2
    slstm_at: tuple = ()  # xlstm
    # frontends
    frontend: str = "none"  # none | audio | vision
    frontend_dim: int = 0
    n_vis_tokens: int = 64
    # misc
    tie_embeddings: bool = False
    remat: bool = True
    # capabilities (drive dry-run cell selection; see DESIGN.md)
    supports_decode: bool = True
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str  # attn | moe_attn | mamba | mlstm | slstm | zshared
    n: int
    attn: attn_mod.AttnStatic | None = None
    mamba: ssm_mod.MambaStatic | None = None
    xl: xl_mod.XLSTMStatic | None = None


def _attn_static(cfg: ArchConfig, is_global: bool = False) -> attn_mod.AttnStatic:
    window = 0
    if cfg.attn_pattern == "swa":
        window = cfg.window
    elif cfg.attn_pattern == "local_global":
        window = 0 if is_global else cfg.window
    return attn_mod.AttnStatic(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads,
        head_dim=cfg.hd,
        causal=cfg.causal,
        window=window,
        rope_theta=cfg.rope_theta_global if is_global else cfg.rope_theta,
        use_rope=cfg.family != "audio",
        mrope=cfg.mrope,
        qk_norm=cfg.qk_norm,
        use_bias=cfg.use_bias,
        norm=cfg.norm,
    )


def build_segments(cfg: ArchConfig) -> list[Segment]:
    att_kind = "moe_attn" if cfg.n_experts else "attn"
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if cfg.attn_pattern == "local_global":
            kinds = [
                ("attn_g" if (i % (cfg.lg_ratio + 1)) == cfg.lg_ratio else "attn_l")
                for i in range(cfg.n_layers)
            ]
            segs: list[Segment] = []
            i = 0
            while i < cfg.n_layers:
                j = i
                while j < cfg.n_layers and kinds[j] == kinds[i]:
                    j += 1
                segs.append(
                    Segment(
                        att_kind,
                        j - i,
                        attn=_attn_static(cfg, is_global=kinds[i] == "attn_g"),
                    )
                )
                i = j
            return segs
        return [Segment(att_kind, cfg.n_layers, attn=_attn_static(cfg))]
    if cfg.family == "ssm":
        xl = xl_mod.XLSTMStatic(d_model=cfg.d_model, n_heads=cfg.n_heads,
                                norm=cfg.norm)
        kinds = [
            "slstm" if i in cfg.slstm_at else "mlstm" for i in range(cfg.n_layers)
        ]
        segs = []
        i = 0
        while i < cfg.n_layers:
            j = i
            while j < cfg.n_layers and kinds[j] == kinds[i]:
                j += 1
            segs.append(Segment(kinds[i], j - i, xl=xl))
            i = j
        return segs
    if cfg.family == "hybrid":
        mst = ssm_mod.MambaStatic(
            d_model=cfg.d_model,
            n_heads=2 * cfg.d_model // cfg.ssm_head_dim,
            head_dim=cfg.ssm_head_dim,
            d_state=cfg.ssm_state,
            norm=cfg.norm,
        )
        segs = []
        left = cfg.n_layers
        k = cfg.shared_attn_every
        while left > 0:
            take = min(k, left)
            segs.append(Segment("mamba", take, mamba=mst))
            left -= take
            if left > 0 or take == k:
                segs.append(Segment("zshared", 1, attn=_attn_static(cfg)))
        return segs
    raise ValueError(cfg.family)


# ---------------------------------------------------------------- init

def _block_init(key, cfg: ArchConfig, seg: Segment):
    if seg.kind in ("attn", "moe_attn"):
        k1, k2 = jax.random.split(key)
        p, s = {}, {}
        p["attn"], s["attn"] = attn_mod.attn_init(k1, seg.attn)
        if seg.kind == "moe_attn":
            p["moe"], s["moe"] = moe_mod.moe_init(
                k2, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.ffn_kind, cfg.norm
            )
        else:
            p["ffn"], s["ffn"] = ffn_mod.ffn_init(
                k2, cfg.d_model, cfg.d_ff, cfg.ffn_kind, cfg.norm, cfg.use_bias
            )
        return p, s
    if seg.kind == "mamba":
        return ssm_mod.mamba_init(key, seg.mamba)
    if seg.kind == "mlstm":
        return xl_mod.mlstm_init(key, seg.xl)
    if seg.kind == "slstm":
        return xl_mod.slstm_init(key, seg.xl)
    raise ValueError(seg.kind)


def _zshared_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["w_in"], s["w_in"] = linear_init(ks[0], 2 * cfg.d_model, cfg.d_model)
    p["attn"], s["attn"] = attn_mod.attn_init(ks[1], _attn_static(cfg))
    p["ffn"], s["ffn"] = ffn_mod.ffn_init(
        ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_kind, cfg.norm
    )
    p["w_out"], s["w_out"] = linear_init(ks[3], cfg.d_model, cfg.d_model)
    return p, s


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, cfg: ArchConfig):
    """Returns (params, specs). Pure; usable under jax.eval_shape."""
    segments = build_segments(cfg)
    keys = jax.random.split(key, len(segments) + 4)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = embed_init(
        keys[-1], cfg.vocab_size, cfg.d_model
    )
    if cfg.frontend != "none":
        params["front_proj"], specs["front_proj"] = linear_init(
            keys[-2], cfg.frontend_dim, cfg.d_model
        )
    seg_params, seg_specs = [], []
    for i, seg in enumerate(segments):
        if seg.kind == "zshared":
            seg_params.append({})
            seg_specs.append({})
            continue
        if seg.n == 1:
            p, s = _block_init(keys[i], cfg, seg)
        else:
            ps = [
                _block_init(k, cfg, seg)
                for k in jax.random.split(keys[i], seg.n)
            ]
            p = _stack([x[0] for x in ps])
            s = jax.tree.map(
                lambda ax: ("layers",) + ax,
                ps[0][1],
                is_leaf=lambda x: isinstance(x, tuple),
            )
        seg_params.append(p)
        seg_specs.append(s)
    params["segments"] = seg_params
    specs["segments"] = seg_specs
    if any(s.kind == "zshared" for s in segments):
        params["shared"], specs["shared"] = _zshared_init(keys[-3], cfg)
    params["final_ln"], specs["final_ln"] = norm_init(cfg.norm, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = linear_init(
            keys[-4], cfg.d_model, cfg.vocab_size, out_axis="vocab"
        )
    return params, specs


# --------------------------------------------------------------- caches

def _block_cache(cfg: ArchConfig, seg: Segment, batch: int, max_len: int,
                 mx_digital: bool = False, fused: bool = False):
    if seg.kind in ("attn", "moe_attn", "zshared"):
        return attn_mod.attn_cache_init(seg.attn, batch, max_len,
                                        mx_digital=mx_digital, fused=fused)
    if seg.kind == "mamba":
        return ssm_mod.mamba_cache_init(seg.mamba, batch)
    if seg.kind == "mlstm":
        return xl_mod.mlstm_cache_init(seg.xl, batch)
    if seg.kind == "slstm":
        return xl_mod.slstm_cache_init(seg.xl, batch)
    raise ValueError(seg.kind)


def _block_cache_specs(seg: Segment, mx_digital: bool = False,
                       fused: bool = False):
    if seg.kind in ("attn", "moe_attn", "zshared"):
        return attn_mod.attn_cache_specs(mx_digital, fused=fused)
    if seg.kind == "mamba":
        return ssm_mod.MAMBA_CACHE_SPECS
    if seg.kind == "mlstm":
        return xl_mod.MLSTM_CACHE_SPECS
    if seg.kind == "slstm":
        return xl_mod.SLSTM_CACHE_SPECS
    raise ValueError(seg.kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               mx_digital: bool = False, fused: bool = False):
    """Decode caches per segment (stacked along the layer axis for runs).

    ``mx_digital`` adds the quantized-resident K/V code mirrors that make
    per-token decode quantization O(1) in cache length on the hybrid /
    fully-digital MXFP4 SDPA path (bitwise identical to the
    requant-per-step reference a plain cache falls back to). ``fused``
    selects the head-interleaved paged layout for attention segments —
    decode then runs the ragged paged flash-decode path (see
    ``kernels.paged_attention``)."""
    caches = []
    for seg in build_segments(cfg):
        c = _block_cache(cfg, seg, batch, max_len, mx_digital=mx_digital,
                         fused=fused)
        if seg.n > 1:
            c = jax.tree.map(lambda x: jnp.broadcast_to(x, (seg.n,) + x.shape), c)
        caches.append(c)
    return caches


def cache_specs(cfg: ArchConfig, mx_digital: bool = False,
                fused: bool = False):
    out = []
    for seg in build_segments(cfg):
        s = dict(_block_cache_specs(seg, mx_digital=mx_digital,
                                    fused=fused))
        if seg.n > 1:
            s = {k: ("layers",) + v for k, v in s.items()}
        out.append(s)
    return out


# -------------------------------------------------------------- forward

def _block_apply(ctx, cfg, seg: Segment, p, x, positions, cache, pos, shared,
                 x0, rope_tables=None):
    if seg.kind in ("attn", "moe_attn"):
        x, nc = attn_mod.attn_apply(ctx.scoped("attn"), seg.attn, p["attn"],
                                    x, positions, cache, pos,
                                    rope_tables=rope_tables)
        if seg.kind == "moe_attn":
            x = moe_mod.moe_apply(
                ctx.scoped("moe"), cfg.ffn_kind, cfg.norm, p["moe"], x,
                cfg.top_k, cfg.capacity_factor,
            )
        else:
            x = ffn_mod.ffn_apply(ctx.scoped("ffn"), cfg.ffn_kind, cfg.norm,
                                  p["ffn"], x)
        return x, nc
    if seg.kind == "mamba":
        return ssm_mod.mamba_apply(ctx, seg.mamba, p, x, cache)
    if seg.kind == "mlstm":
        return xl_mod.mlstm_apply(ctx, seg.xl, p, x, cache)
    if seg.kind == "slstm":
        return xl_mod.slstm_apply(ctx, seg.xl, p, x, cache)
    if seg.kind == "zshared":
        # shared-block params live under the top-level "shared" tree path,
        # so the capture scope resets (not appends) — every zshared call
        # taps the same resident weights, as in the physical array
        sctx = ctx if (
            ctx.tap is None and ctx.fidelity is None
        ) else dataclasses.replace(ctx, scope="shared")
        h = linear_apply(sctx, shared["w_in"],
                         jnp.concatenate([x, x0], axis=-1), name="w_in")
        h, nc = attn_mod.attn_apply(sctx.scoped("attn"), seg.attn,
                                    shared["attn"], h, positions, cache, pos,
                                    rope_tables=rope_tables)
        h = ffn_mod.ffn_apply(sctx.scoped("ffn"), cfg.ffn_kind, cfg.norm,
                              shared["ffn"], h)
        return x + linear_apply(sctx, shared["w_out"], h,
                                name="w_out").astype(x.dtype), nc
    raise ValueError(seg.kind)


def _run_segment(ctx, cfg, seg: Segment, p, x, positions, cache, pos, shared, x0):
    # RoPE tables depend only on positions: compute them once per segment
    # and share across q/k and every scanned layer (the scan body closes
    # over them) instead of re-deriving sin/cos per layer per projection
    rope_tables = None
    if (
        seg.attn is not None
        and seg.attn.use_rope
        and not seg.attn.mrope
    ):
        rope_tables = ropelib.rope_tables(
            positions, seg.attn.head_dim, seg.attn.rope_theta
        )
    if seg.n == 1 or seg.kind == "zshared":
        return _block_apply(ctx, cfg, seg, p, x, positions, cache, pos,
                            shared, x0, rope_tables)

    if ctx.tap is not None or ctx.fidelity is not None or ctx.unroll_layers:
        # calibration capture / fidelity probing (each per-layer activation
        # records under its own "L<j>" scope; scan would trace the host
        # callbacks away) or explicit unrolled execution for bitwise
        # numerics comparisons
        ncs = []
        for j in range(seg.n):
            pj = jax.tree.map(lambda a: a[j], p)
            cj = None if cache is None else jax.tree.map(lambda a: a[j], cache)
            x, nc = _block_apply(ctx.scoped(f"L{j}"), cfg, seg, pj, x,
                                 positions, cj, pos, shared, x0, rope_tables)
            ncs.append(nc)
        nc = None if cache is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *ncs
        )
        return x, nc

    def body(carry, xs):
        if cache is None:
            pl, cl = xs, None
        else:
            pl, cl = xs
        y, nc = _block_apply(ctx, cfg, seg, pl, carry, positions, cl, pos,
                             shared, x0, rope_tables)
        return y, nc

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = p if cache is None else (p, cache)
    x, ncs = jax.lax.scan(body, x, xs)
    return x, ncs


def embed_inputs(ctx: RunCtx, cfg: ArchConfig, params, batch):
    if cfg.frontend == "audio":
        x = linear_apply(ctx, params["front_proj"], batch["emb"],
                         name="front_proj")
        s = x.shape[1]
        # sinusoidal positions (frontend stub; HuBERT's conv-pos simplified)
        pos = jnp.arange(s)
        dim = cfg.d_model
        inv = 1.0 / (10000 ** (jnp.arange(0, dim, 2) / dim))
        pe = jnp.concatenate(
            [jnp.sin(pos[:, None] * inv), jnp.cos(pos[:, None] * inv)], -1
        )
        return (x + pe[None].astype(x.dtype)).astype(jnp.bfloat16)
    x = jnp.take(params["embed"]["emb"].astype(jnp.bfloat16), batch["ids"],
                 axis=0)
    if cfg.frontend == "vision" and "vis_emb" in batch:
        v = linear_apply(ctx, params["front_proj"], batch["vis_emb"],
                         name="front_proj")
        nv = v.shape[1]
        x = jnp.concatenate([v.astype(x.dtype), x[:, nv:]], axis=1)
    return x


def forward(
    params,
    cfg: ArchConfig,
    ctx: RunCtx,
    batch: dict,
    caches=None,
    pos=None,
    return_hidden: bool = False,
):
    """batch: {'ids' | 'emb', optional 'positions'}. Returns
    (logits_or_hidden, new_caches)."""
    segments = build_segments(cfg)
    x = embed_inputs(ctx, cfg, params, batch)
    x = ctx.act(x, "batch", "seq", "embed")
    b, s, _ = x.shape
    if "positions" in batch:
        positions = batch["positions"]
    elif pos is not None:
        # scalar pos (all lanes aligned) or [B] vector (continuous-batching
        # decode: each lane at its own position)
        pos_arr = jnp.asarray(pos)
        if pos_arr.ndim == 0:
            positions = jnp.broadcast_to(pos_arr[None, None], (b, s))
        else:
            positions = jnp.broadcast_to(pos_arr[:, None], (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x0 = x
    new_caches = []
    for i, seg in enumerate(segments):
        c = caches[i] if caches is not None else None
        x, nc = _run_segment(
            ctx.scoped(f"segments/{i}"), cfg, seg, params["segments"][i], x,
            positions, c, pos, params.get("shared"), x0,
        )
        new_caches.append(nc)
    x = norm_apply(cfg.norm, params["final_ln"], x)
    if return_hidden:
        return x, new_caches
    logits = _head(ctx, cfg, params, x)
    return logits, new_caches


def _head(ctx, cfg, params, x):
    if cfg.tie_embeddings:
        w = params["embed"]["emb"].astype(jnp.bfloat16).T
        logits = jnp.matmul(x, w)
    else:
        logits = linear_apply(ctx, params["lm_head"], x, name="lm_head")
    return ctx.act(logits, "batch", "seq", "vocab")


def lm_loss(params, cfg: ArchConfig, ctx: RunCtx, batch, chunk: int = 1024):
    """Mean CE over labeled tokens, computed in sequence chunks to avoid
    materialising the full [B, S, V] f32 softmax."""
    hidden, _ = forward(params, cfg, ctx, batch, return_hidden=True)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (s + pad) // chunk
    hc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def one(args):
        h, l, m = args
        logits = _head(ctx, cfg, params, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m), jnp.sum(m)

    tot, cnt = jax.lax.map(one, (hc, lc, mc))
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)


def forward_pipelined(params, cfg: ArchConfig, ctx: RunCtx, batch: dict,
                      *, runner=None, stages: int = 1, replicas: int = 1,
                      microbatches: int = 2, mb_size: int = 1, **kw):
    """Stage-parallel pipelined forward over a (replica, stage) device
    mesh: the multi-device counterpart of :func:`forward` for the
    prefill/scoring path (weights resident per stage, microbatches
    overlapped — see ``distributed.pipeline_exec``).

    Returns ``(logits, runner)``; pass the returned ``runner`` back in to
    reuse the placed weights and compiled step across calls."""
    if runner is None:
        from repro.distributed import pipeline_exec as pex

        runner = pex.build_lm_pipeline(
            params, cfg, ctx, stages=stages, replicas=replicas,
            microbatches=microbatches, mb_size=mb_size, **kw,
        )
    return runner.forward(batch), runner


def decode_step(params, cfg: ArchConfig, ctx: RunCtx, ids, pos, caches):
    """One decode step. ids [B, 1]; pos scalar int32 (current position,
    shared by all lanes) or int32 [B] (per-lane positions — the serving
    engine's continuous-batching mode, where each lane advances
    independently). Returns (logits [B, V], new_caches)."""
    batch = {"ids": ids}
    logits, new_caches = forward(params, cfg, ctx, batch, caches=caches, pos=pos)
    return logits[:, -1], new_caches
