"""Encoder-only Vision Transformer on the hybrid CIM layer stack.

This is the paper's own evaluation workload family (Table 7 is ViT/BERT
rows) made *executable* instead of closed-form: patch embedding (unfold +
linear, so it dispatches through ``layers/backends.py`` like every other
static linear), a learned CLS token + position embeddings, pre-LN encoder
blocks reused verbatim from the LM stack (``lm.Segment``/``_run_segment``:
same scan/unroll machinery, same ``segments/<i>/L<j>/...`` capture paths,
so ``models/calibrate.py`` Row-Hist calibration and ``convert_params_cim``
work unchanged), and a classification head over the CLS token.

Encoder semantics: full bidirectional attention (``causal=False``), no
RoPE (positions are learned embeddings), no KV cache and no decode step —
one fixed-shape forward per image. Under the hybrid backend the SDPA runs
the digital MXFP4 systolic path from ``layers/attention.py`` exactly as
for the LMs; QKV/O, FFN, patch embedding and head convert to resident
analog CTT arrays.

Dual-chip deployments (vit-l32: 24 blocks split 12+12, paper §5.3) slice
the layer-stacked trunk with ``distributed.sharding.stage_partition`` —
``split_chips`` + ``forward_chip`` below; ``serving/vision.py`` drives the
chip chain with an explicit inter-chip activation hop.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_mod
from repro.layers.common import (
    RunCtx,
    linear_apply,
    linear_init,
    norm_apply,
    norm_init,
)
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    image_size: int
    patch_size: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    in_channels: int = 3
    head_dim: int = 0
    ffn_kind: str = "gelu"
    norm: str = "layernorm"
    use_bias: bool = True
    remat: bool = False
    chips: int = 1  # FWS stage partition (dual-chip vit-l32 / bert-large)
    # unused by the encoder but read by shared lm machinery signatures
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    @property
    def grid(self) -> int:
        assert self.image_size % self.patch_size == 0, (
            self.image_size, self.patch_size)
        return self.image_size // self.patch_size

    @property
    def n_patches(self) -> int:
        return self.grid * self.grid

    @property
    def seq_len(self) -> int:
        return self.n_patches + 1  # CLS token

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.in_channels

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


def _attn_static(cfg: ViTConfig) -> attn_mod.AttnStatic:
    return attn_mod.AttnStatic(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv=cfg.n_heads,  # encoder ViT/BERT: full MHA, no GQA
        head_dim=cfg.hd,
        causal=False,
        use_rope=False,  # learned absolute position embeddings
        use_bias=cfg.use_bias,
        norm=cfg.norm,
    )


def build_segments(cfg: ViTConfig) -> list[lm.Segment]:
    return [lm.Segment("attn", cfg.n_layers, attn=_attn_static(cfg))]


# ---------------------------------------------------------------- init

def init_model(key, cfg: ViTConfig):
    """Returns (params, specs); same (tree, logical-axis-spec-tree) shape
    contract as ``lm.init_model``."""
    segments = build_segments(cfg)
    keys = jax.random.split(key, len(segments) + 4)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["patch"], specs["patch"] = linear_init(
        keys[-1], cfg.patch_dim, cfg.d_model, use_bias=cfg.use_bias,
        in_axis="conv", out_axis="embed",
    )
    params["cls"] = jax.random.normal(
        keys[-2], (1, 1, cfg.d_model), jnp.float32) * 0.02
    specs["cls"] = (None, None, "embed")
    params["pos"] = jax.random.normal(
        keys[-3], (1, cfg.seq_len, cfg.d_model), jnp.float32) * 0.02
    specs["pos"] = (None, "seq", "embed")
    seg_params, seg_specs = [], []
    for i, seg in enumerate(segments):
        ps = [
            lm._block_init(k, cfg, seg)
            for k in jax.random.split(keys[i], seg.n)
        ]
        p = lm._stack([x[0] for x in ps])
        s = jax.tree.map(
            lambda ax: ("layers",) + ax,
            ps[0][1],
            is_leaf=lambda x: isinstance(x, tuple),
        )
        seg_params.append(p)
        seg_specs.append(s)
    params["segments"] = seg_params
    specs["segments"] = seg_specs
    params["final_ln"], specs["final_ln"] = norm_init(cfg.norm, cfg.d_model)
    params["head"], specs["head"] = linear_init(
        keys[-4], cfg.d_model, cfg.n_classes, use_bias=cfg.use_bias,
        out_axis="vocab",
    )
    return params, specs


# -------------------------------------------------------------- forward

def patchify(images: jax.Array, patch: int) -> jax.Array:
    """[B, H, W, C] -> [B, (H/p)*(W/p), p*p*C] non-overlapping unfold (the
    conv patch embedding expressed as unfold + shared linear, so the
    projection executes through the backend registry)."""
    b, h, w, c = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * gw, patch * patch * c)


def embed_images(ctx: RunCtx, cfg: ViTConfig, params, images) -> jax.Array:
    """Patch-embed + CLS prepend + learned position embeddings."""
    x = patchify(images.astype(jnp.float32), cfg.patch_size)
    x = linear_apply(ctx, params["patch"], x, name="patch")
    cls = jnp.broadcast_to(
        params["cls"].astype(x.dtype), (x.shape[0], 1, cfg.d_model)
    )
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"].astype(x.dtype)
    return x.astype(jnp.bfloat16)


def encode(
    params_seg,
    cfg: ViTConfig,
    ctx: RunCtx,
    x: jax.Array,
    n_layers: int | None = None,
    scope_index: int = 0,
) -> jax.Array:
    """Run the (possibly layer-sliced) stacked encoder trunk."""
    n = n_layers or cfg.n_layers
    seg = lm.Segment("attn", n, attn=_attn_static(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if n == 1:
        # vit params are always layer-stacked (uniform conversion paths);
        # lm._run_segment's n==1 shortcut expects unstacked params
        params_seg = jax.tree.map(lambda a: a[0], params_seg)
    x, _ = lm._run_segment(
        ctx.scoped(f"segments/{scope_index}"), cfg, seg, params_seg, x,
        positions, None, None, None, x,
    )
    return x


def head(ctx: RunCtx, cfg: ViTConfig, params, x: jax.Array) -> jax.Array:
    """Final LN + CLS pool + classifier -> [B, n_classes]."""
    x = norm_apply(cfg.norm, params["final_ln"], x)
    logits = linear_apply(ctx, params["head"], x[:, :1], name="head")
    return ctx.act(logits, "batch", "seq", "vocab")[:, 0]


def forward(
    params,
    cfg: ViTConfig,
    ctx: RunCtx,
    batch: dict,
    caches=None,
    pos=None,
    return_hidden: bool = False,
):
    """batch: {'images': [B, H, W, C] float}. Returns (logits [B, classes]
    or hidden [B, S, d], None) — the ``(out, new_caches)`` contract of
    ``lm.forward`` with no cache (encoders have none), so the calibration
    capture and serving plumbing treat both model families uniformly."""
    del caches, pos  # encoder: no KV cache, no decode step
    x = embed_images(ctx, cfg, params, batch["images"])
    x = ctx.act(x, "batch", "seq", "embed")
    x = encode(params["segments"][0], cfg, ctx, x)
    if return_hidden:
        return norm_apply(cfg.norm, params["final_ln"], x), None
    return head(ctx, cfg, params, x), None


def forward_pipelined(params, cfg: ViTConfig, ctx: RunCtx, batch: dict,
                      *, runner=None, stages: int | None = None,
                      replicas: int = 1, microbatches: int = 2,
                      mb_size: int = 1, **kw):
    """Stage-parallel pipelined encoder forward on a real device mesh —
    the executable form of the §5.3 multi-chip FWS deployment that
    ``split_chips``/``forward_chip`` below only chain sequentially.

    Returns ``(logits, runner)``; reuse the returned ``runner`` to keep
    the per-stage resident weights and compiled step."""
    if runner is None:
        from repro.distributed import pipeline_exec as pex

        runner = pex.build_vit_pipeline(
            params, cfg, ctx, stages=stages or cfg.chips,
            replicas=replicas, microbatches=microbatches, mb_size=mb_size,
            **kw,
        )
    return runner.forward(batch), runner


# ------------------------------------------------------- chip partition

def split_chips(params, cfg: ViTConfig, n_chips: int | None = None):
    """Slice the layer-stacked trunk into per-chip param trees using the
    balanced contiguous ``distributed.sharding.stage_partition`` (vit-l32:
    24 layers -> 12+12). Chip 0 keeps the embedding front (patch/cls/pos);
    the last chip keeps final_ln + head. Works on float, MXFP4-packed and
    CIM-converted trees alike: every stacked leaf (weights, codes, exps,
    per-layer ``e_n``/``adc_fs`` calib) carries the layer axis first."""
    from repro.distributed.sharding import stage_partition

    n_chips = n_chips or cfg.chips
    bounds = stage_partition(cfg.n_layers, n_chips)
    chips = []
    for ci, (lo, hi) in enumerate(bounds):
        sub: dict[str, Any] = {
            "segments": [
                jax.tree.map(lambda a: a[lo:hi], params["segments"][0])
            ],
        }
        if ci == 0:
            for k in ("patch", "cls", "pos"):
                sub[k] = params[k]
        if ci == n_chips - 1:
            sub["final_ln"] = params["final_ln"]
            sub["head"] = params["head"]
        chips.append((sub, hi - lo))
    return chips


def forward_chip(
    chip_params,
    cfg: ViTConfig,
    ctx: RunCtx,
    inp,
    n_layers: int,
    first: bool,
    last: bool,
):
    """One chip's share of the pipeline: ``inp`` is the image batch on the
    first chip, the previous chip's hidden state (the inter-chip hop
    payload) otherwise. Returns logits on the last chip, hidden else."""
    if first:
        x = embed_images(ctx, cfg, chip_params, inp)
    else:
        x = inp.astype(jnp.bfloat16)
    x = encode(chip_params["segments"][0], cfg, ctx, x, n_layers=n_layers)
    if last:
        return head(ctx, cfg, chip_params, x)
    return x


# ----------------------------------------------------------- calibration

def calibration_images(cfg: ViTConfig, n_batches: int = 2, batch: int = 2,
                       seed: int = 1234):
    """Synthetic representative image batches for smoke-scale Row-Hist
    calibration (the vision analogue of ``calibrate.calibration_batches``)."""
    out = []
    for i in range(n_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        out.append({
            "images": jax.random.normal(
                key,
                (batch, cfg.image_size, cfg.image_size, cfg.in_channels),
                jnp.float32,
            )
        })
    return out
