"""Model-wide Row-Hist calibration + hybrid analog/digital conversion.

The paper's deployment flow (§3.2.1, §4.3): run a handful of
representative batches through the model *offline*, record the input
activations of every static linear, pick each layer's target exponent
``E_N`` from the observed block-output-exponent distribution (zero
overflow => max), calibrate the ADC full scale at that ``E_N``, then burn
the MXFP4 weights into the CTT arrays as resident INT5 codes. At serving
time those layers execute on the analog ``cim_analog`` backend while
dynamic compute (SDPA, MoE dispatch) stays on the digital MXFP4 path.

The capture run executes *eagerly* with scanned segments unrolled (see
``lm._run_segment``) so per-layer activations record under their
param-tree paths; conversion re-keys stacked segments so ``lax.scan``
slices per-layer calibration exactly like the weights.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import cim as cimlib
from repro.layers import backends
from repro.layers.common import RunCtx
from repro.models import lm


def capture_rowhist_calibration(
    params,
    cfg,
    ctx: RunCtx,
    batches,
    *,
    cim_cfg: cimlib.CIMConfig | None = None,
    min_n: int = 256,
    max_rows: int = 512,
    calib_quant: str = "mxfp4_digital",
    wq_cache: dict | None = None,
    forward_fn=None,
) -> dict[str, cimlib.LayerCalib]:
    """Run ``batches`` (list of model-input dicts) through the model with
    an ActivationTap and return ``{param-tree path: LayerCalib}`` for every
    static analog-eligible linear. Runs eagerly — do not call under jit.

    ``forward_fn(params, cfg, ctx, batch)`` selects the model family
    (default ``lm.forward``; pass ``vit.forward`` for encoders) — the
    capture is model-agnostic: any forward that routes its static linears
    through ``linear_apply`` with stable param-tree-path names calibrates.

    The capture executes on the *digital MXFP4* path by default
    (``calib_quant="mxfp4_digital"``), not bf16 float: at serving time each
    analog layer sees activations produced by quantized upstream layers, so
    calibrating on the matched distribution keeps the Row-Hist max-exponent
    guarantee (zero overflow) valid at deployment. With a lossless CIM
    config this makes the hybrid model *exactly* the digital MXFP4 model.
    """
    forward_fn = forward_fn or lm.forward
    tap = backends.ActivationTap(min_n=min_n, max_rows=max_rows)
    cap_ctx = dataclasses.replace(ctx, quant=calib_quant, tap=tap, scope="")
    for batch in batches:
        forward_fn(params, cfg, cap_ctx, batch)
    return backends.calibrate_taps(
        tap, cim_cfg or cimlib.CIMConfig(), wq_cache=wq_cache
    )


def capture_linear_inputs(
    params,
    cfg,
    ctx: RunCtx,
    batch,
    *,
    quant: str | None = None,
    min_n: int = 32,
    max_rows: int = 512,
    forward_fn=None,
    fidelity=None,
):
    """One eager forward with an ``include_converted`` ActivationTap:
    returns ``({param-tree path: float32 [rows, k] activations}, output)``
    — the raw material of the per-layer SQNR tracer. Run it once on a
    reference tree/backend and once on the instrumented one, then compare
    captures path-by-path (``repro.obs.fidelity.sqnr_trace``); the tap's
    row subsampling is deterministic in shape, so both runs keep identical
    rows. Paths visited more than once (the Zamba shared block) record
    multiple entries, concatenated here in visit order.

    ``quant=None`` keeps ``ctx.quant``; pass a :class:`FidelityProbe` as
    ``fidelity`` to collect quantizer/ADC health metrics in the same
    forward instead of paying a second instrumented run.
    """
    forward_fn = forward_fn or lm.forward
    tap = backends.ActivationTap(
        min_n=min_n, max_rows=max_rows, include_converted=True
    )
    rep: dict = {"tap": tap, "scope": ""}
    if quant is not None:
        rep["quant"] = quant
    if fidelity is not None:
        rep["fidelity"] = fidelity
    out = forward_fn(params, cfg, dataclasses.replace(ctx, **rep), batch)
    caps = {
        path: np.concatenate([np.asarray(a) for a in xs], axis=0)
        for path, xs in tap.records.items()
    }
    return caps, out


def convert_model_cim(
    params,
    cfg,
    ctx: RunCtx,
    batches,
    *,
    cim_cfg: cimlib.CIMConfig | None = None,
    min_n: int = 256,
    max_rows: int = 512,
    forward_fn=None,
):
    """Full offline pipeline: capture -> Row-Hist calibrate -> convert.

    Returns ``(converted_params, calibs)``. The converted tree holds
    resident INT5 codes + exponents + per-layer calib for the analog
    layers, packed MXFP4 for MoE expert banks, bf16 for everything else.
    Serve with ``RunCtx(quant="cim", cim=cim_cfg)``. ``forward_fn``
    selects the model family (default ``lm.forward``, see
    :func:`capture_rowhist_calibration`).
    """
    cim_cfg = cim_cfg or cimlib.CIMConfig()
    wq_cache: dict = {}  # quantize each analog weight once, not twice
    calibs = capture_rowhist_calibration(
        params, cfg, ctx, batches,
        cim_cfg=cim_cfg, min_n=min_n, max_rows=max_rows, wq_cache=wq_cache,
        forward_fn=forward_fn,
    )
    converted = backends.convert_params_cim(
        params, calibs, min_n=min_n, wq_cache=wq_cache
    )
    return converted, calibs


def calibration_batches(cfg, n_batches: int = 4, batch: int = 4,
                        seq: int = 32, seed: int = 1234):
    """Synthetic representative batches (random token ids) for smoke-scale
    calibration when no dataset is wired in."""
    out = []
    for i in range(n_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        out.append({
            "ids": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
        })
    return out
