"""Hardware constants and workload specs for the MXFormer analytical
performance model (paper Tables 1-9, Fig 12). Derivations in perf.py."""

from __future__ import annotations

import dataclasses

ANALOG_CLK = 169e6  # Hz (paper §5)
DIGITAL_CLK = 1e9
BITPLANES = 5  # INT5 bit-serial input streaming
MUX = 2  # bit-line multiplexing degree (derived from Table 3)
PASSES = 2  # Row-Hist 2-Pass (halves analog throughput)
CM_BITS = 3
ADC_BITS = 10
CTT_BITS_PER_CELL = 5

# Table 3 (macro, 22nm FDSOI; area mm^2; derived checks in tests)
MACRO = {
    768: {"area_mm2": 1.78, "tops_1pass": 20.02, "tops_w": 58.83,
          "tops_mm2": 11.26},
    1024: {"area_mm2": 2.97, "tops_1pass": 35.72, "tops_w": 75.72,
           "tops_mm2": 12.02},
}

# Table 5 component area/power (constants as published; CTT derived)
COMPONENTS = {
    "base": {
        "systolic_area": 58.25, "systolic_power": 87.51,
        "vector_area": 14.54, "vector_power": 16.82,
        "quant_area": 7.89, "quant_power": 6.99,
        "transp_area": 1.15, "transp_power": 1.10,
        "buffer_area": 2.05, "buffer_power": 1.70,
        "sram_area": 34.98, "sram_power": 0.12,
    },
    "large": {
        "systolic_area": 58.25, "systolic_power": 85.23,
        "vector_area": 17.35, "vector_power": 19.14,
        "quant_area": 7.89, "quant_power": 6.91,
        "transp_area": 1.15, "transp_power": 1.07,
        "buffer_area": 2.73, "buffer_power": 2.26,
        "sram_area": 46.43, "sram_power": 0.20,
    },
}


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    name: str
    hidden: int  # CTT array edge (768 Base / 1024 Large)
    n_blocks: int = 12  # Transformer blocks per die
    arrays_per_block: int = 12  # 4 proj + 2 FFN "large arrays" of 4 each
    # digital: two 32x64 output-stationary systolic arrays per block
    sa_rows: int = 32
    sa_cols: int = 64
    # calibrated digital per-layer time constant (see perf.py):
    #   T_d = C_D0 * (d_model/768) * ceil32(N) * ceil64(N) [seconds]
    # single calibration point: BERT-Base @ N=512 = 9,055 seq/s (Table 7)


BASE = SystemSpec("base", 768)
LARGE = SystemSpec("large", 1024)
C_D0 = 1.0 / (9055 * 512 * 512) / (768 / 768)  # = 0.4213 ns

# Paper workload models (encoder, d/L/heads/params/seq at max input size)
@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    d: int
    layers: int
    seq: int
    params_m: float  # backbone params (millions)
    chips: int = 1
    system: str = "base"


WORKLOADS = {
    "vit-b32": Workload("vit-b32", 768, 12, 50, 88),  # CLIP vision tower
    "vit-b16": Workload("vit-b16", 768, 12, 197, 86),
    "vit-b14": Workload("vit-b14", 768, 12, 257, 86),  # DINOv2
    "vit-s16": Workload("vit-s16", 384, 12, 197, 22),
    "bert-base": Workload("bert-base", 768, 12, 512, 110),
    "vit-l32": Workload("vit-l32", 1024, 24, 145, 307, chips=2, system="large"),
    "vit-l14": Workload("vit-l14", 1024, 24, 257, 304, chips=2, system="large"),
    "bert-large": Workload("bert-large", 1024, 24, 512, 340, chips=2,
                           system="large"),
    "bert-large-128": Workload("bert-large-128", 1024, 24, 128, 340, chips=2,
                               system="large"),
    # DeiT-B/16 shares ViT-B/16 geometry (d=768, 12 layers, N=197, 86M
    # backbone params): the paper reports it only in Table 9 (SOTA
    # comparison, 41,269 img/s on Base) — PAPER_TABLE9 below, validated in
    # tests/test_hwmodel.py next to the Table 7 sweep. It has no separate
    # Table 1 row because the identical (N, d, params) makes its I/O
    # penalty figures coincide with vit-b16's (also pinned in tests).
    "deit-b16": Workload("deit-b16", 768, 12, 197, 86),
}

# Paper-reported results for validation (Table 4 & Table 7)
PAPER_TABLE4 = {
    "base": {"area_mm2": 376.3, "power_w": 163.16, "tops": 1515.14,
             "tops_mm2": 4.04, "tops_w": 9.29},
    "large": {"area_mm2": 561.5, "power_w": 182.61, "tops": 2631.56,
              "tops_mm2": 4.69, "tops_w": 14.41},
}
PAPER_TABLE7 = {  # model -> (power_w, fps, tops)
    "vit-b32": (96.5, 169000, 1451),
    "vit-b16": (170.6, 41269, 1440),
    "vit-b14": (161.1, 25716, 1204),
    "bert-base": (147.1, 9055, 875),
    "vit-s16": (122.2, 42893, 389),
    "vit-l32": (385.5, 58275, 5224),
    "vit-l14": (327.4, 19839, 3208),
    "bert-large": (299.2, 6983, 2338),
}
PAPER_TABLE9 = {  # model -> fps (SOTA comparison; fps-only rows)
    "deit-b16": 41269,
}
PAPER_TABLE1 = {  # model -> (penalty_max_batch, max_batch, penalty_b1)
    "bert-base": (1.93, 150, 140),
    "bert-large": (3.86, 112, 320),
    "vit-b16": (1.73, 391, 285),
    "vit-b32": (1.73, 1542, 1120),
    "vit-l32": (3.59, 398, 1029),
}

# Table 2 NVM comparison (for the density benchmark)
NVM = {
    "nor_flash": {"cell_f2": 10, "read_ns": 50, "max_bits": 3},
    "reram": {"cell_f2": 27, "read_ns": 15, "max_bits": 4},
    "feram": {"cell_f2": 21, "read_ns": 35, "max_bits": 3},
    "pcm": {"cell_f2": 27, "read_ns": 12.5, "max_bits": 4},
    "ctt": {"cell_f2": 5, "read_ns": 7.5, "max_bits": 6},
}

A100_L2_BYTES = 30e6  # Table 1 persistent L2

# Dual-chip deployments (vit-l32 / bert-large: 24 blocks split 12+12)
# forward activations across a chip-to-chip link between stage 12 and 13.
# The paper treats the hop as pipeline-hidden; this models it as one extra
# pipeline stage moving N*d bf16 activations at a conservative link rate,
# which stays far below stage_time for every Table-7 shape.
INTERCHIP_GBPS = 100.0
