"""MXFormer analytical performance/area/power model.

Derivations (validated against the paper in tests/test_hwmodel.py):

Analog macro (Table 3):
    TOPS_1pass = 2 * H^2 * f_analog / (BITPLANES * MUX)
    (768: 19.93 vs 20.02 paper; 1024: 35.44 vs 35.72 — <1.5%)

Pipeline (§5.3): every CTT array consumes one token per
BITPLANES*MUX*PASSES = 20 analog cycles, so
    T_analog(N) = N * 20 / 169 MHz           (per stage, 2-pass)
The digital stage runs the two 32x64 systolic arrays over
tile-quantized attention matmuls:
    T_digital(N, d) = C_D0 * (d/768) * ceil32(N) * ceil64(N)
with C_D0 calibrated once from BERT-Base (N=512, digital-bound,
9,055 seq/s). Steady-state throughput = 1/max(T_a, T_d) — this
reproduces all eight Table-7 FPS figures within ~4% (most <1%).

I/O penalty (Table 1): weights fp16, per-item activation traffic
4 B/elem (in+out bf16), resident activations 0.5 B/elem (FP4):
    B* = floor(30 MB / (N*d*0.5));  penalty(B) = 1 + W/(B*N*d*4)
"""

from __future__ import annotations

import math

from repro.hwmodel import specs as S


def ceil_to(n: int, m: int) -> int:
    """Round ``n`` up to a multiple of ``m`` (systolic tile quantization:
    the digital stage processes attention matmuls in 32x64 tiles, so
    ``t_digital`` bills ceil32(N) * ceil64(N))."""
    return -(-n // m) * m


# ------------------------------------------------------------- macro model

def macro_tops(hidden: int, passes: int = 1) -> float:
    return 2 * hidden * hidden * S.ANALOG_CLK / (
        S.BITPLANES * S.MUX * passes
    ) / 1e12


def macro_area_mm2(hidden: int) -> float:
    return S.MACRO[hidden]["area_mm2"]


def macro_power_w(hidden: int) -> float:
    return S.MACRO[hidden]["tops_1pass"] / S.MACRO[hidden]["tops_w"]


def storage_density_kb_mm2(hidden: int) -> float:
    bits = hidden * hidden * S.CTT_BITS_PER_CELL
    return bits / 1e3 / macro_area_mm2(hidden)


# ------------------------------------------------------------ system model

def n_arrays(sys: S.SystemSpec) -> int:
    return sys.n_blocks * sys.arrays_per_block


def analog_tops(sys: S.SystemSpec, passes: int = S.PASSES) -> float:
    return n_arrays(sys) * macro_tops(sys.hidden, passes)


def digital_peak_tops(sys: S.SystemSpec) -> float:
    macs = 2 * sys.sa_rows * sys.sa_cols  # two arrays per block
    return sys.n_blocks * macs * 2 * S.DIGITAL_CLK / 1e12


def t_analog(n_tokens: int, passes: int = S.PASSES) -> float:
    cyc = S.BITPLANES * S.MUX * passes
    return n_tokens * cyc / S.ANALOG_CLK


def t_digital(n_tokens: int, d_model: int) -> float:
    return (
        S.C_D0
        * (d_model / 768.0)
        * ceil_to(n_tokens, 32)
        * ceil_to(n_tokens, 64)
    )


def stage_time(n_tokens: int, d_model: int) -> float:
    return max(t_analog(n_tokens), t_digital(n_tokens, d_model))


def t_interchip(n_tokens: int, d_model: int) -> float:
    """One inter-chip hop in a multi-chip FWS pipeline (vit-l32 /
    bert-large: 24 blocks split 12+12): the [N, d] bf16 activation tile
    crosses the chip-to-chip link. Far below ``stage_time`` for every
    Table-7 shape, so the hop adds latency but never bounds throughput."""
    return n_tokens * d_model * 2 / (S.INTERCHIP_GBPS * 1e9)


def steady_state_fps(n_tokens: int, d_model: int = 768) -> float:
    """Steady-state items/s of the fully weight-stationary pipeline once
    every stage is occupied: one item leaves the last block every
    ``stage_time`` (§5.3), so FPS = 1 / max(T_analog, T_digital).

    This is the quantity reported per model in Table 7 — e.g. rows
    ``vit-b16`` (N=197, d=768 -> 41,269 fps), ``bert-base`` (N=512,
    d=768 -> 9,055 fps), ``vit-l14``/``bert-large`` (d=1024, Large
    system) — and is what ``serving/pipeline.py``'s discrete-event model
    must converge to once its twelve stages fill."""
    return 1.0 / stage_time(n_tokens, d_model)


def n_balance(sys: S.SystemSpec) -> float:
    """Sequence length where analog and digital stage times cross."""
    # t_a = 20N/f ; t_d ~ C_D0*(d/768)*N^2  (ignoring tile quantization)
    return (20 / S.ANALOG_CLK) / (S.C_D0 * sys.hidden / 768.0)


def flops_per_item(w: S.Workload) -> float:
    """Encoder inference FLOPs: linear 24*d^2/token + attention 4*N*d."""
    return w.seq * w.layers * (24 * w.d * w.d + 4 * w.seq * w.d)


def fps(w: S.Workload) -> float:
    return 1.0 / stage_time(w.seq, w.d)


def tops(w: S.Workload) -> float:
    return flops_per_item(w) * fps(w) / 1e12


def system_peak_tops(sys: S.SystemSpec) -> float:
    nb = round(n_balance(sys))
    t = stage_time(nb, sys.hidden)
    util_d = t_digital(nb, sys.hidden) / t
    return analog_tops(sys) + digital_peak_tops(sys) * min(util_d, 1.0)


def system_area_mm2(sys: S.SystemSpec) -> float:
    c = S.COMPONENTS[sys.name]
    ctt = n_arrays(sys) * macro_area_mm2(sys.hidden)
    return ctt + sum(v for k, v in c.items() if k.endswith("_area"))


def system_power_w(sys: S.SystemSpec, util_a: float = 1.0,
                   util_d: float = 1.0) -> float:
    c = S.COMPONENTS[sys.name]
    ctt = n_arrays(sys) * macro_power_w(sys.hidden)
    digital = sum(v for k, v in c.items() if k.endswith("_power"))
    return ctt * util_a + digital * util_d


def model_power_w(w: S.Workload) -> float:
    sys = S.BASE if w.system == "base" else S.LARGE
    t = stage_time(w.seq, w.d)
    util_a = t_analog(w.seq) / t
    util_d = min(t_digital(w.seq, w.d) / t, 1.0)
    return w.chips * system_power_w(sys, util_a, util_d)


# --------------------------------------------------------------- Table 1

def io_penalty(w: S.Workload):
    """(penalty at max batch, max batch, penalty at batch 1)."""
    weights = w.params_m * 1e6 * 2  # fp16 bytes
    act_traffic = w.seq * w.d * 4.0  # in+out bf16 per item
    act_resident = w.seq * w.d * 0.5  # FP4 resident
    bmax = int(S.A100_L2_BYTES // act_resident)

    def penalty(b):
        return 1.0 + weights / (b * act_traffic)

    return penalty(bmax), bmax, penalty(1)


# --------------------------------------------------------------- Fig 12

def fig12_sweep(sys: S.SystemSpec = S.BASE, ns=None):
    ns = ns or [16, 32, 64, 96, 128, 192, 256, 320, 384, 448, 512]
    rows = []
    for n in ns:
        w = S.Workload("sweep", sys.hidden, sys.n_blocks, n, 0)
        rows.append({
            "N": n,
            "t_analog_us": t_analog(n) * 1e6,
            "t_digital_us": t_digital(n, sys.hidden) * 1e6,
            "t_stage_us": stage_time(n, sys.hidden) * 1e6,
            "tops": tops(w),
            "fps": fps(w),
        })
    return rows


# ------------------------------------------------------- Tables 4/7 builds

def table4():
    out = {}
    for sys in (S.BASE, S.LARGE):
        peak = system_peak_tops(sys)
        area = system_area_mm2(sys)
        nb = round(n_balance(sys))
        t = stage_time(nb, sys.hidden)
        power = system_power_w(
            sys, t_analog(nb) / t, min(t_digital(nb, sys.hidden) / t, 1.0)
        )
        out[sys.name] = {
            "tops": peak, "area_mm2": area, "power_w": power,
            "tops_mm2": peak / area, "tops_w": peak / power,
            "n_balance": nb,
        }
    return out


def table7():
    out = {}
    for name, w in S.WORKLOADS.items():
        if name not in S.PAPER_TABLE7 and name not in ("bert-large-128",
                                                       "deit-b16"):
            continue
        sys = S.BASE if w.system == "base" else S.LARGE
        f = fps(w)
        out[name] = {
            "fps": f,
            "tops": tops(w),
            "power_w": model_power_w(w),
            "tops_mm2": tops(w) / (w.chips * system_area_mm2(sys)),
            "tops_w": tops(w) / model_power_w(w),
        }
    return out
