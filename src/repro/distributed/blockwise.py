"""Block-level roofline analysis.

XLA's HloCostAnalysis counts a while-loop body ONCE (not x trip count), so
cost_analysis() on the full scanned-layer model undercounts FLOPs/bytes/
collectives by ~n_layers. The full-model compile remains the existence +
memory proof; *this* module compiles ONE block per distinct segment
signature with exactly the shardings the model uses, reads its per-device
cost + collective schedule from XLA, and composes totals with the known
trip counts:

  train:   sum_seg n_seg * (fwd+bwd block cost) * k_micro
           + (stem + head&loss) * k_micro + optimizer
  serve:   sum_seg n_seg * fwd block cost + stem + head

Per-block compiles use the dense attention path (exact quadratic FLOPs —
the chunked-scan flash path would be undercounted); blocks whose sequence
is too large to compile densely are fitted with a two-point quadratic
cost model a*S + b*S^2 measured at S0 and 2*S0 (exact for this codebase,
where masking does not skip tiles — a §Perf item). Recurrent xLSTM cells
are counted as block_cost + (S-1) * per-step cell cost (cell compiled
standalone). The tiny inter-chunk SSD state scan (O(b*h*p*n) per chunk)
is the only remaining undercount — negligible and documented.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.distributed import roofline as rl
from repro.distributed import sharding as shd
from repro.layers.common import RunCtx, convert_params_mxfp4, convert_specs_mxfp4
from repro.models import lm
from repro.optim import adamw

DENSE_MAX = 4096  # largest seq compiled densely per block


def _cost_of(fn, args, shardings, mesh, n_dev):
    jitted = jax.jit(fn, in_shardings=shardings)
    with mesh:
        compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text(), n_dev)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.wire_bytes,
    }


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "wire": 0.0}


def _acc(tot, c, mult=1.0):
    for k in tot:
        tot[k] += c[k] * mult
    return tot


def _seg_structs(cfg, seg):
    box = {}

    def only_p():
        if seg.kind == "zshared":
            p, s = lm._zshared_init(jax.random.PRNGKey(0), cfg)
        else:
            p, s = lm._block_init(jax.random.PRNGKey(0), cfg, seg)
        box["specs"] = s
        return p

    return jax.eval_shape(only_p), box["specs"]


def _block_fn(cfg, seg, ctx, positions, pos, with_x0):
    def fn(p, x, cache=None):
        shared = p if seg.kind == "zshared" else None
        pp = {} if seg.kind == "zshared" else p
        x0 = x if with_x0 else None
        y, nc = lm._block_apply(
            ctx, cfg, seg, pp, x, positions, cache, pos, shared, x0
        )
        return (y, nc) if cache is not None else y

    return fn


def _sig(seg):
    return (seg.kind, seg.attn, seg.mamba, seg.xl)


def analyze_cell(
    cfg,
    shape: C.Shape,
    mesh,
    quant: str | None = None,
    fsdp: bool = True,
    k_micro: int | None = None,
) -> dict:
    """Trip-count-exact per-device roofline totals for one cell."""
    from repro.launch import steps as steps_mod

    n_dev = mesh.devices.size
    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[
        shape.kind
    ]
    is_train = shape.kind == "train"
    quant = quant or ("mxfp4_ste_prequant" if is_train else "mxfp4_wonly")
    rules = shd.make_rules(cfg, mesh, mode, batch_size=shape.batch)
    ctx = RunCtx(
        shd=shd.ShardingCtx(mesh=mesh, rules=rules),
        quant=quant,
        decode=shape.kind == "decode",
        dense_attn_max=1 << 30,  # dense path: exact attention FLOPs
        unroll_scans=True,  # count chunk-loop trips exactly
    )
    pctx = shd.ShardingCtx(
        mesh=mesh,
        rules=steps_mod.param_rules(rules, mesh, fsdp and is_train),
    )
    if k_micro is None:
        k_micro = steps_mod.pick_microbatches(mesh, shape) if is_train else 1
    b = shape.batch // k_micro
    d = cfg.d_model

    segments = lm.build_segments(cfg)
    counts: dict[Any, int] = {}
    rep: dict[Any, Any] = {}
    for seg in segments:
        key = _sig(seg)
        counts[key] = counts.get(key, 0) + seg.n
        rep[key] = seg

    total = _zero()
    details = {}

    def _xsh(bb, ss):
        return shd.resolve_with_divisibility(
            ("batch", "seq", "embed"),
            jax.ShapeDtypeStruct((bb, ss, d), jnp.bfloat16), ctx.shd, mesh,
        )

    for key, seg in rep.items():
        n = counts[key]
        pstruct, specs = _seg_structs(cfg, seg)
        if quant == "mxfp4_wonly":
            qstruct = jax.eval_shape(convert_params_mxfp4, pstruct)
            specs = convert_specs_mxfp4(specs, pstruct)
            pstruct = qstruct
        elif quant == "mxfp4_ste_prequant":
            from repro.layers.common import quantize_weights_tree

            pstruct = jax.eval_shape(quantize_weights_tree, pstruct)
        p_shard = shd.resolve_with_divisibility(specs, pstruct, pctx, mesh)

        def block_cost(s_eval, with_grad):
            def fwd(p, x):
                posn = jnp.broadcast_to(jnp.arange(s_eval)[None], (b, s_eval))
                fn = _block_fn(cfg, seg, ctx, posn, None, seg.kind == "zshared")
                return fn(p, x)

            xs = jax.ShapeDtypeStruct((b, s_eval, d), jnp.bfloat16)
            x_spec = _xsh(b, s_eval)
            if not with_grad:
                return _cost_of(fwd, (pstruct, xs), (p_shard, x_spec), mesh,
                                n_dev)

            def fwd_bwd(p, x, ct):
                y, vjp = jax.vjp(jax.checkpoint(fwd), p, x)
                dp, dx = vjp(ct)
                return y, dp, dx

            return _cost_of(
                fwd_bwd, (pstruct, xs, xs), (p_shard, x_spec, x_spec), mesh,
                n_dev,
            )

        if shape.kind == "decode":
            cstruct = jax.eval_shape(
                lambda sg=seg: lm._block_cache(cfg, sg, shape.batch, shape.seq)
            )
            cspecs = lm._block_cache_specs(seg)
            c_shard = shd.resolve_with_divisibility(cspecs, cstruct, ctx.shd,
                                                    mesh)

            def dec(p, x, cache):
                posn = jnp.full((shape.batch, 1), shape.seq - 1, jnp.int32)
                shared = p if seg.kind == "zshared" else None
                pp = {} if seg.kind == "zshared" else p
                y, nc = lm._block_apply(
                    ctx, cfg, seg, pp, x, posn,
                    cache, jnp.int32(shape.seq - 1), shared,
                    x if seg.kind == "zshared" else None,
                )
                return y, nc

            xs = jax.ShapeDtypeStruct((shape.batch, 1, d), jnp.bfloat16)
            xsh = _xsh(shape.batch, 1)
            c = _cost_of(dec, (pstruct, xs, cstruct),
                         (p_shard, xsh, c_shard), mesh, n_dev)
        elif shape.seq <= DENSE_MAX:
            c = block_cost(shape.seq, is_train)
        else:
            s0 = DENSE_MAX // 2
            c1 = block_cost(s0, is_train)
            c2 = block_cost(2 * s0, is_train)
            c = {}
            for kk in c1:
                bq = (c2[kk] - 2 * c1[kk]) / (2 * s0 * s0)
                aq = (c1[kk] - bq * s0 * s0) / s0
                c[kk] = max(aq * shape.seq + bq * shape.seq**2, 0.0)

        # sLSTM recurrent cells: + (S-1) x per-step cost (x3 for fwd+bwd)
        # (mLSTM is chunkwise-parallel now and fully counted via unroll)
        if seg.kind == "slstm" and shape.kind != "decode":
            cell = _cell_step_cost(cfg, seg, b, mesh, ctx, n_dev)
            steps_mult = (shape.seq - 1) * (3.0 if is_train else 1.0)
            c = _acc(dict(c), cell, mult=steps_mult)

        mult = n * (k_micro if is_train else 1)
        _acc(total, c, mult)
        details[str(key[0]) + f"_n{n}"] = {**c, "mult": mult}

    # stem (embedding) + head (+ loss & grads) per microbatch
    stem_head = _stem_head_cost(cfg, shape, mesh, ctx, pctx, quant, b,
                                is_train, n_dev)
    _acc(total, stem_head, mult=k_micro if is_train else 1)
    details["stem_head"] = stem_head

    if is_train:
        optc = _optimizer_cost(cfg, mesh, pctx, n_dev)
        _acc(total, optc)
        details["optimizer"] = optc
        if quant == "mxfp4_ste_prequant":
            wq = _weight_quant_cost(cfg, mesh, pctx, n_dev)
            _acc(total, wq)
            details["weight_quant"] = wq

    coll = rl.CollectiveStats(wire_bytes=total["wire"])
    terms = rl.roofline_terms(
        {"flops": total["flops"], "bytes accessed": total["bytes"]},
        coll, n_dev,
    )
    terms["k_micro"] = k_micro
    terms["details"] = details
    return terms


def serve_layer_costs(cfg, n_tokens: int) -> list[float]:
    """Closed-form per-layer forward FLOP estimates for serving-time stage
    balancing (``sharding.stage_partition(mode="balanced")``).

    Unlike :func:`analyze_cell` (the measured, XLA-compiled path) this is a
    cheap analytic model — static-linear matmul FLOPs plus the quadratic
    (window-clipped) SDPA term — because stage cuts only need *relative*
    per-layer weights, not absolute rooflines. Non-attention block kinds
    get projection-dominated estimates; they cannot ride the stage-parallel
    executor anyway (see ``distributed.pipeline_exec``) but keep the cost
    vector aligned with the layer index space."""
    from repro.models import vit as vit_mod

    is_vit = isinstance(cfg, vit_mod.ViTConfig)
    segs = (vit_mod.build_segments if is_vit else lm.build_segments)(cfg)
    N = int(n_tokens)
    d = cfg.d_model
    glu = cfg.ffn_kind in ("swiglu", "geglu")
    costs: list[float] = []
    for seg in segs:
        for _ in range(seg.n):
            if seg.kind in ("attn", "moe_attn", "zshared"):
                a = seg.attn
                proj = 2 * N * d * a.n_heads * a.head_dim * 2  # q + o
                proj += 2 * N * d * a.n_kv * a.head_dim * 2  # k + v
                eff = min(N, a.window) if a.window else N
                sdpa = 4 * N * eff * a.n_heads * a.head_dim  # qk^T + pv
                n_mats = 3 if glu else 2
                ffn = 2 * N * n_mats * d * cfg.d_ff
                if seg.kind == "moe_attn":
                    ffn *= max(cfg.top_k, 1)
                if seg.kind == "zshared":
                    proj += 2 * N * (2 * d) * d + 2 * N * d * d  # w_in/w_out
                costs.append(float(proj + sdpa + ffn))
            elif seg.kind == "mamba":
                m = seg.mamba
                inner = m.n_heads * m.head_dim
                proj = 2 * N * d * (2 * inner + 2 * m.n_heads * m.d_state)
                proj += 2 * N * inner * d  # out projection
                scan = 4 * N * m.n_heads * m.head_dim * m.d_state
                costs.append(float(proj + scan))
            elif seg.kind in ("mlstm", "slstm"):
                # qkv/gate + out projections dominate the recurrent cell
                costs.append(float(2 * N * d * 4 * d + 2 * N * 2 * d * d))
            else:
                raise ValueError(seg.kind)
    return costs


def _cell_step_cost(cfg, seg, b, mesh, ctx, n_dev):
    from repro.layers import xlstm as xl

    st = seg.xl
    h = st.n_heads
    rep = NamedSharding(mesh, P())
    bsh = shd.resolve_with_divisibility(
        ("batch",), jax.ShapeDtypeStruct((b,), jnp.int32), ctx.shd, mesh
    )

    def shard_like(shape_):
        names = [("batch",)[0] if i == 0 else None for i in range(len(shape_))]
        return NamedSharding(mesh, ctx.shd.resolve(tuple(names)))

    if seg.kind == "mlstm":
        dk = st.head_dim
        carry = (
            jax.ShapeDtypeStruct((b, h, dk, dk), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dk), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        )
        inp = (
            jax.ShapeDtypeStruct((b, h, dk), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dk), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dk), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        )

        def step(c, i):
            return xl._mlstm_step(c, i, dk**-0.5)

        sh = (
            tuple(shard_like(x.shape) for x in carry),
            tuple(shard_like(x.shape) for x in inp),
        )
        return _cost_of(step, (carry, inp), sh, mesh, n_dev)
    dh = st.s_head_dim
    carry = tuple(
        jax.ShapeDtypeStruct((b, h, dh), jnp.float32) for _ in range(4)
    )
    wx = jax.ShapeDtypeStruct((b, h, 4 * dh), jnp.float32)
    r = jax.ShapeDtypeStruct((h, dh, 4 * dh), jnp.float32)

    def step(c, i, rr):
        return xl._slstm_step(c, i, rr)

    sh = (
        tuple(shard_like(x.shape) for x in carry),
        shard_like(wx.shape),
        NamedSharding(mesh, ctx.shd.resolve((None, None, "mlp"))),
    )
    return _cost_of(step, (carry, wx, r), sh, mesh, n_dev)


def _stem_head_cost(cfg, shape, mesh, ctx, pctx, quant, b, is_train, n_dev):
    d = cfg.d_model
    v = cfg.vocab_size
    s = shape.seq if shape.kind != "decode" else 1
    bb = b if is_train else shape.batch
    emb = jax.ShapeDtypeStruct((v, d), jnp.float32 if is_train else jnp.bfloat16)
    emb_sh = shd.resolve_with_divisibility(
        ("vocab", "embed"), emb, pctx, mesh
    )
    hid = jax.ShapeDtypeStruct((bb, s, d), jnp.bfloat16)
    hid_sh = shd.resolve_with_divisibility(("batch", "seq", "embed"), hid,
                                           ctx.shd, mesh)
    ids = jax.ShapeDtypeStruct((bb, s), jnp.int32)
    ids_sh = shd.resolve_with_divisibility(("batch", "seq"), ids, ctx.shd, mesh)

    if is_train:

        def head(embw, hidden, labels):
            def lf(w):
                logits = jnp.matmul(hidden, w.astype(jnp.bfloat16).T).astype(
                    jnp.float32
                )
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, labels[..., None], axis=-1
                )[..., 0]
                return jnp.mean(lse - gold)

            return jax.value_and_grad(lf)(embw)

        return _cost_of(head, (emb, hid, ids), (emb_sh, hid_sh, ids_sh),
                        mesh, n_dev)

    def head_i(embw, hidden, idx):
        x = jnp.take(embw.astype(jnp.bfloat16), idx, axis=0)
        logits = jnp.matmul(
            hidden[:, -1].astype(jnp.bfloat16), embw.astype(jnp.bfloat16).T
        )
        return jnp.argmax(logits, -1), x

    return _cost_of(head_i, (emb, hid, ids), (emb_sh, hid_sh, ids_sh),
                    mesh, n_dev)


def _optimizer_cost(cfg, mesh, pctx, n_dev):
    from repro.launch import steps as steps_mod

    pstruct, specs = steps_mod.param_structs(cfg)
    p_shard = shd.resolve_with_divisibility(specs, pstruct, pctx, mesh)
    ostruct = jax.eval_shape(adamw.init, pstruct)
    o_shard = adamw.OptState(
        step=NamedSharding(mesh, P()), m=p_shard, v=p_shard
    )
    ocfg = adamw.AdamWConfig()

    def opt(params, grads, state):
        return adamw.apply(ocfg, params, grads, state)

    return _cost_of(opt, (pstruct, pstruct, ostruct),
                    (p_shard, p_shard, o_shard), mesh, n_dev)


def _weight_quant_cost(cfg, mesh, pctx, n_dev):
    """Once-per-step weight fake-quant (sharded, local)."""
    from repro.launch import steps as steps_mod
    from repro.layers.common import quantize_weights_tree

    pstruct, specs = steps_mod.param_structs(cfg)
    p_shard = shd.resolve_with_divisibility(specs, pstruct, pctx, mesh)
    return _cost_of(quantize_weights_tree, (pstruct,), (p_shard,), mesh, n_dev)
