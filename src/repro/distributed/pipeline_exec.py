"""Real multi-device FWS pipeline execution (shard_map stage parallelism).

``serving/pipeline.py`` *models* the paper's §5.3 twelve-stage fully-
weight-stationary pipeline as discrete events; this module makes the
dataflow real on a jax device mesh:

- ``stage_partition`` maps contiguous layer ranges onto a ``stage`` mesh
  axis; each stage's (possibly CIM-converted) trunk weights are placed
  **once** with ``jax.device_put(..., NamedSharding(mesh, P("stage")))``
  and never move again — the FWS premise. A transfer guard
  (:meth:`StagePipeline.collectives`) proves it from the compiled HLO:
  the steady-state step contains only ``collective-permute`` ops whose
  wire traffic is activation-sized.
- Activations stream stage-to-stage with ``jax.lax.ppermute`` over a
  rotating GPipe-style microbatch schedule: one jitted ``shard_map`` body
  unrolls the ``T = n_microbatches + n_stages - 1`` fill/steady/drain
  steps, so at steady state all stages compute concurrently on
  consecutive microbatches.
- A leading ``replica`` mesh axis runs data-parallel pipeline replicas
  (microbatch groups block-partitioned over replicas inside the same
  step); :class:`ReplicaRouter` is the trivial round-robin front door.

Stage cuts come from ``sharding.stage_partition`` — equal layer counts by
default, or cost-balanced (``mode="balanced"``) from
``blockwise.serve_layer_costs``. Unequal cuts pad every stage's slice to
the longest stage (repeating the last layer's params) and mask the padded
scan steps out with the per-stage layer count; the equal-cut path skips
the mask entirely so it stays op-for-op identical to the single-device
``lm._run_segment`` scan (bitwise parity, see tests/test_pipeline_exec.py).

Only single-homogeneous-attention-segment models (dense LMs, ViTs) are
supported: heterogeneous segment chains (local/global runs, hybrid SSM)
have per-segment block signatures that cannot share one scanned stage
body. Everything else — float / packed-MXFP4 / CIM-converted trees —
works unchanged because every stacked leaf (weights, codes, exps,
per-layer calib) carries the layer axis first.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.layers import rope as ropelib
from repro.layers.common import RunCtx, ShardingCtx, norm_apply
from repro.models import lm

__all__ = [
    "StagePipeline",
    "ReplicaRouter",
    "MeasuredReport",
    "make_pipeline_mesh",
    "build_lm_pipeline",
    "build_vit_pipeline",
]


def make_pipeline_mesh(stages: int, replicas: int = 1) -> Mesh:
    """(replica, stage) mesh over the first ``replicas * stages`` devices.

    On CPU-only machines force a multi-device platform first, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = stages * replicas
    have = jax.device_count()
    if n > have:
        raise ValueError(
            f"pipeline mesh needs {replicas}x{stages} = {n} devices, have "
            f"{have} (hint: XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={n} on CPU)"
        )
    devs = np.array(jax.devices()[:n]).reshape(replicas, stages)
    return Mesh(devs, ("replica", "stage"))


def _local_ctx(ctx: RunCtx) -> RunCtx:
    """The stage body runs *inside* shard_map: per-device execution with no
    further mesh to constrain against, so drop any sharding rules."""
    if ctx.shd.mesh is None:
        return ctx
    return dataclasses.replace(ctx, shd=ShardingCtx())


def _make_stage_fn(cfg, ctx: RunCtx, seg: lm.Segment, masked: bool):
    """One pipeline stage: scan the local layer slice over the microbatch.

    Mirrors ``lm._run_segment`` exactly on the equal-cut path (hoisted RoPE
    tables, same scan body, same remat wrapper) so the pipelined forward
    stays bitwise-comparable to the single-device one; ``masked`` adds the
    padded-layer passthrough for unequal (cost-balanced) cuts.
    """
    sctx = _local_ctx(ctx)
    remat = bool(getattr(cfg, "remat", False))

    def stage_fn(p_stack, n_local, x):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        rope_tables = None
        if seg.attn is not None and seg.attn.use_rope and not seg.attn.mrope:
            rope_tables = ropelib.rope_tables(
                positions, seg.attn.head_dim, seg.attn.rope_theta
            )

        def body(carry, xs):
            if masked:
                j, pl = xs
            else:
                pl = xs
            y, _ = lm._block_apply(sctx, cfg, seg, pl, carry, positions,
                                   None, None, None, None, rope_tables)
            if masked:
                y = jnp.where(j < n_local, y, carry)
            return y, None

        if remat:
            body = jax.checkpoint(body)
        max_l = jax.tree.leaves(p_stack)[0].shape[0]
        xs = (jnp.arange(max_l), p_stack) if masked else p_stack
        x, _ = jax.lax.scan(body, x, xs)
        return x

    return stage_fn


def _stack_stages(trunk, bounds):
    """Layer-stacked trunk [L, ...] -> per-stage stack [S, max_L, ...].

    Stages shorter than the longest one are padded by repeating their last
    layer's params (the padded scan steps are masked out in the stage
    body), so every leaf keeps one uniform shape shardable as P("stage").
    """
    max_l = max(hi - lo for lo, hi in bounds)

    def leaf(a):
        slabs = []
        for lo, hi in bounds:
            s = a[lo:hi]
            if hi - lo < max_l:
                pad = jnp.repeat(a[hi - 1:hi], max_l - (hi - lo), axis=0)
                s = jnp.concatenate([s, pad], axis=0)
            slabs.append(s)
        return jnp.stack(slabs)

    return jax.tree.map(leaf, trunk), max_l


def _pad_rows(tree, cap: int):
    """Pad every leaf's leading (batch) axis to ``cap`` rows by repeating
    the last row — the ragged-final-microbatch filler."""

    def f(a):
        n = a.shape[0]
        if n == cap:
            return a
        return jnp.concatenate(
            [a, jnp.repeat(a[-1:], cap - n, axis=0)], axis=0
        )

    return jax.tree.map(f, tree)


@dataclasses.dataclass(frozen=True)
class MeasuredReport:
    """Pipeline health measured from real multi-device runs (the measured
    counterpart of the simulated ``serving.pipeline.PipelineReport``)."""

    name: str
    n_stages: int
    n_replicas: int
    microbatches: int  # per replica
    mb_size: int
    step_wall_s: float  # one full fill+steady+drain step (min over reps)
    stage_walls_s: tuple  # one microbatch through each stage, isolated
    throughput_items_per_s: float  # rows per step wall (fill included)
    steady_items_per_s: float  # drain rate implied by the bottleneck stage
    bubble_fraction: float  # mean stage idle fraction over the step wall
    fill_latency_s: float  # first microbatch through all stages (estimate)

    @property
    def stage_occupancy(self) -> tuple:
        """Busy fraction of each stage over the step wall."""
        if not self.step_wall_s:
            return tuple(0.0 for _ in self.stage_walls_s)
        return tuple(
            min(1.0, self.microbatches * w / self.step_wall_s)
            for w in self.stage_walls_s
        )

    def publish(self, registry, prefix: str = "pipeline_measured") -> None:
        """Export measured gauges next to the simulated ``pipeline_*``
        family so ``scripts/metrics_summary.py`` renders both."""
        g = registry.gauge
        for i, (w, occ) in enumerate(
            zip(self.stage_walls_s, self.stage_occupancy)
        ):
            g(f"{prefix}_stage_wall_seconds",
              "one microbatch through this stage (measured, isolated)",
              labels={"stage": str(i)}).set(w)
            g(f"{prefix}_stage_occupancy",
              "measured busy fraction of this stage over the step wall",
              labels={"stage": str(i)}).set(occ)
        g(f"{prefix}_bubble_fraction",
          "measured mean stage idle fraction over the step wall").set(
            self.bubble_fraction)
        g(f"{prefix}_fill_latency_seconds",
          "measured first-microbatch traversal of the stage chain").set(
            self.fill_latency_s)
        g(f"{prefix}_step_wall_seconds",
          "one fill+steady+drain pipeline step").set(self.step_wall_s)
        g(f"{prefix}_throughput_items_per_s",
          "rows per step wall, fill included").set(
            self.throughput_items_per_s)
        g(f"{prefix}_steady_state_fps",
          "drain rate implied by the measured bottleneck stage").set(
            self.steady_items_per_s)
        g(f"{prefix}_stages", "pipeline depth").set(float(self.n_stages))
        g(f"{prefix}_replicas", "data-parallel pipeline replicas").set(
            float(self.n_replicas))


class StagePipeline:
    """Stage-parallel executor: resident per-stage weights, overlapping
    microbatches, one jitted shard_map step.

    Built via :func:`build_lm_pipeline` / :func:`build_vit_pipeline`. The
    embed front and the final-norm/head back run outside the shard_map
    body on replicated params: the trunk step's HLO then contains *only*
    the stage-to-stage ``collective-permute`` — the transfer guard that
    pins the weights-never-move invariant.
    """

    def __init__(self, *, mesh: Mesh, bounds, trunk, front, back,
                 embed_fn: Callable, stage_fn: Callable, head_fn: Callable,
                 microbatches: int, mb_size: int, name: str = "model"):
        if set(mesh.axis_names) != {"replica", "stage"}:
            raise ValueError(f"need a (replica, stage) mesh, got "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.bounds = list(bounds)
        self.name = name
        self.n_stages = mesh.shape["stage"]
        self.n_replicas = mesh.shape["replica"]
        if len(self.bounds) != self.n_stages:
            raise ValueError(
                f"{len(self.bounds)} stage cuts for a {self.n_stages}-stage "
                f"mesh"
            )
        self.microbatches = int(microbatches)
        self.mb_size = int(mb_size)
        if self.microbatches < 1 or self.mb_size < 1:
            raise ValueError("need microbatches >= 1 and mb_size >= 1")
        self.lengths = [hi - lo for lo, hi in self.bounds]

        stacked, self.max_layers = _stack_stages(trunk, self.bounds)
        stage_sh = NamedSharding(mesh, P("stage"))
        rep_sh = NamedSharding(mesh, P())
        # resident placement: done once, never repeated (FWS premise)
        self.trunk = jax.device_put(stacked, stage_sh)
        self.n_locals = jax.device_put(
            jnp.asarray(self.lengths, jnp.int32), stage_sh
        )
        self.front = jax.device_put(front, rep_sh)
        self.back = jax.device_put(back, rep_sh)

        S_ = self.n_stages
        M = self.microbatches
        T = M + S_ - 1
        perm = [(i, (i + 1) % S_) for i in range(S_)]

        def body(tr, nl, xg):
            # tr: this stage's params [1, max_L, ...]; nl: [1] local layer
            # count; xg: this replica's microbatches [M, mb, s, d]
            tr = jax.tree.map(lambda a: a[0], tr)
            n_local = nl[0]
            sidx = jax.lax.axis_index("stage")
            carry = jnp.zeros_like(xg[0])
            out = jnp.zeros_like(xg)
            for t in range(T):  # unrolled GPipe fill/steady/drain schedule
                x = jnp.where(sidx == 0, xg[min(t, M - 1)], carry)
                y = stage_fn(tr, n_local, x)
                o = t - (S_ - 1)
                if o >= 0:
                    out = out.at[o].set(
                        jnp.where(sidx == S_ - 1, y, out[o])
                    )
                if S_ > 1 and t < T - 1:
                    carry = jax.lax.ppermute(y, "stage", perm)
            return out[None]

        self._step = jax.jit(
            shard_map(
                body,
                mesh=mesh,
                in_specs=(P("stage"), P("stage"), P("replica")),
                out_specs=P("stage", "replica"),
                check_rep=False,
            )
        )
        self._embed = jax.jit(embed_fn)
        self._head = jax.jit(head_fn)
        self._stage_fn = stage_fn
        self._last_report: MeasuredReport | None = None

    # --------------------------------------------------------- execution

    @property
    def capacity(self) -> int:
        """Rows one step processes: replicas x microbatches x mb_size."""
        return self.n_replicas * self.microbatches * self.mb_size

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    @property
    def trunk_bytes(self) -> int:
        return sum(a.nbytes for a in jax.tree.leaves(self.trunk))

    def forward_raw(self, batch: dict):
        """Exactly ``capacity`` rows -> outputs for every row."""
        x = self._embed(self.front, batch)
        g = self.n_replicas * self.microbatches
        buf = self._step(
            self.trunk, self.n_locals,
            x.reshape(g, self.mb_size, *x.shape[1:]),
        )
        y = buf[-1]  # last stage's drain buffer holds the results
        y = y.reshape(self.capacity, *y.shape[2:])
        return self._head(self.back, y)

    def forward(self, batch: dict):
        """Any 1..capacity rows: pads the ragged final microbatch (row
        repeats), runs one pipeline step, slices the real rows back out."""
        n = jax.tree.leaves(batch)[0].shape[0]
        if not 1 <= n <= self.capacity:
            raise ValueError(f"batch of {n} rows exceeds pipeline capacity "
                             f"{self.capacity}")
        out = self.forward_raw(_pad_rows(batch, self.capacity))
        return out[:n]

    def timed_forward(self, batch: dict):
        t0 = time.perf_counter()
        out = jax.block_until_ready(self.forward(batch))
        wall = time.perf_counter() - t0
        return out, wall

    # ------------------------------------------------------ transfer guard

    def step_hlo(self, batch: dict) -> str:
        """Compiled HLO of the steady-state trunk step (weights resident —
        everything crossing devices shows up here as a collective)."""
        x = self._embed(self.front, _pad_rows(batch, self.capacity))
        g = self.n_replicas * self.microbatches
        lowered = self._step.lower(
            self.trunk, self.n_locals,
            x.reshape(g, self.mb_size, *x.shape[1:]),
        )
        return lowered.compile().as_text()

    def collectives(self, batch: dict):
        """CollectiveStats of the trunk step. The FWS invariant: only
        ``collective-permute`` (the activation hop) may appear, and its
        wire traffic is activation-sized — far below the trunk bytes."""
        from repro.distributed import roofline as rl

        return rl.parse_collectives(self.step_hlo(batch), self.n_devices)

    def trunk_resident(self) -> bool:
        """Every trunk leaf is sharded over the stage axis (placed once at
        construction; nothing below ever re-places it)."""
        def ok(a):
            spec = a.sharding.spec
            return len(spec) > 0 and spec[0] == "stage"

        return all(ok(a) for a in jax.tree.leaves(self.trunk))

    # -------------------------------------------------------- measurement

    def measure_stage_walls(self, batch: dict, reps: int = 3) -> list[float]:
        """Wall time of one microbatch through each stage in isolation,
        chaining each stage's true input activations (measurement-only
        host copies; the resident placement is untouched)."""
        x = self._embed(self.front, _pad_rows(batch, self.capacity))
        x = jax.device_get(x[: self.mb_size])
        walls = []
        for i in range(self.n_stages):
            p_i = jax.tree.map(lambda a: jax.device_get(a[i]), self.trunk)
            n_i = jnp.int32(self.lengths[i])
            fn = jax.jit(lambda p, xx, n=n_i: self._stage_fn(p, n, xx))
            y = jax.block_until_ready(fn(p_i, x))  # compile + warm
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                y = jax.block_until_ready(fn(p_i, x))
                best = min(best, time.perf_counter() - t0)
            walls.append(best)
            x = y
        return walls

    def measure_step_wall(self, batch: dict, reps: int = 3) -> float:
        """Min wall of the trunk shard_map step alone (embed/head and the
        host-side pad/slice excluded) — exactly the T-step GPipe schedule
        the ``serving.pipeline`` discrete-event model predicts, so this is
        the measured side of the cross-validation in
        ``benchmarks/run.py::pipeline_multidevice``."""
        x = self._embed(self.front, _pad_rows(batch, self.capacity))
        g = self.n_replicas * self.microbatches
        xg = x.reshape(g, self.mb_size, *x.shape[1:])
        jax.block_until_ready(self._step(self.trunk, self.n_locals, xg))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(self._step(self.trunk, self.n_locals, xg))
            best = min(best, time.perf_counter() - t0)
        return best

    def measure(self, batch: dict, reps: int = 3) -> MeasuredReport:
        """Measured pipeline health for one representative batch: full-step
        wall (min over ``reps``), isolated per-stage walls, and the
        occupancy / bubble / fill figures they imply."""
        self.forward(batch)  # compile + warm
        best = float("inf")
        for _ in range(reps):
            _, wall = self.timed_forward(batch)
            best = min(best, wall)
        stage_walls = self.measure_stage_walls(batch, reps=reps)
        t_stage = max(stage_walls)
        m = self.microbatches
        occ = [min(1.0, m * w / best) for w in stage_walls] if best else []
        bubble = max(0.0, 1.0 - sum(occ) / len(occ)) if occ else 0.0
        fill = sum(stage_walls)
        steady = (
            self.n_replicas * self.mb_size / t_stage if t_stage else 0.0
        )
        rep = MeasuredReport(
            name=self.name,
            n_stages=self.n_stages,
            n_replicas=self.n_replicas,
            microbatches=m,
            mb_size=self.mb_size,
            step_wall_s=best,
            stage_walls_s=tuple(stage_walls),
            throughput_items_per_s=self.capacity / best if best else 0.0,
            steady_items_per_s=steady,
            bubble_fraction=bubble,
            fill_latency_s=fill,
        )
        self._last_report = rep
        return rep

    def publish(self, registry, prefix: str = "pipeline_measured") -> None:
        if self._last_report is None:
            raise ValueError("call measure() before publish()")
        self._last_report.publish(registry, prefix=prefix)


class ReplicaRouter:
    """Trivial round-robin front door over the pipeline's data-parallel
    replicas: each submitted batch claims the next replica slot (at most
    ``microbatches * mb_size`` rows); ``flush`` packs full replica groups
    into single pipeline steps and returns per-ticket outputs."""

    def __init__(self, runner: StagePipeline):
        self.runner = runner
        self._pending: list = []  # (ticket, batch, n_rows)
        self._next_ticket = 0
        self.dispatched = [0] * runner.n_replicas  # batches per replica

    @property
    def slot_rows(self) -> int:
        return self.runner.microbatches * self.runner.mb_size

    def submit(self, batch: dict) -> int:
        n = jax.tree.leaves(batch)[0].shape[0]
        if not 1 <= n <= self.slot_rows:
            raise ValueError(
                f"batch of {n} rows exceeds replica slot ({self.slot_rows})"
            )
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, batch, n))
        return ticket

    def flush(self) -> dict:
        """Run all pending batches; returns {ticket: output rows}."""
        out: dict = {}
        r = self.runner.n_replicas
        pending, self._pending = self._pending, []
        for g0 in range(0, len(pending), r):
            group = pending[g0:g0 + r]
            slots = [
                _pad_rows(b, self.slot_rows) for _, b, _ in group
            ]
            while len(slots) < r:  # idle replicas replay slot 0
                slots.append(slots[0])
            packed = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *slots
            )
            ys = self.runner.forward_raw(packed)
            for si, (ticket, _, n) in enumerate(group):
                out[ticket] = ys[si * self.slot_rows:si * self.slot_rows + n]
                self.dispatched[si] += 1
        return out


# ---------------------------------------------------------------- builders

def _resolve_bounds(cfg, stages: int, mode: str, costs, seq_len: int):
    from repro.distributed import blockwise
    from repro.distributed.sharding import stage_partition

    if mode == "balanced" and costs is None:
        costs = blockwise.serve_layer_costs(cfg, seq_len)
    return stage_partition(cfg.n_layers, stages, mode=mode, costs=costs)


def _finish(cfg, ctx, seg, *, mesh, stages, replicas, bounds, trunk, front,
            back, embed_fn, head_fn, microbatches, mb_size, name):
    mesh = mesh or make_pipeline_mesh(stages, replicas)
    masked = len({hi - lo for lo, hi in bounds}) > 1
    stage_fn = _make_stage_fn(cfg, ctx, seg, masked)
    return StagePipeline(
        mesh=mesh, bounds=bounds, trunk=trunk, front=front, back=back,
        embed_fn=embed_fn, stage_fn=stage_fn, head_fn=head_fn,
        microbatches=microbatches, mb_size=mb_size, name=name,
    )


def build_lm_pipeline(params, cfg, ctx: RunCtx, *, stages: int,
                      replicas: int = 1, microbatches: int = 2,
                      mb_size: int = 1, seq_len: int = 512,
                      mesh: Mesh | None = None, mode: str = "equal",
                      costs=None) -> StagePipeline:
    """Stage-parallel pipelined forward for a dense LM (prefill/scoring
    path — the per-token decode step stays on the existing engine).

    Works on float, packed-MXFP4 and CIM-converted param trees alike; the
    ``ctx`` selects the backend exactly as for ``lm.forward``.
    """
    segs = lm.build_segments(cfg)
    if len(segs) != 1 or segs[0].kind != "attn":
        raise NotImplementedError(
            "stage-parallel pipeline needs a single homogeneous attention "
            f"trunk; {cfg.name} has segments "
            f"{[(s.kind, s.n) for s in segs]}"
        )
    seg = segs[0]
    trunk = params["segments"][0]
    if seg.n == 1:
        # n==1 segments store unstacked block params; give them the layer
        # axis every stacked leaf carries
        trunk = jax.tree.map(lambda a: a[None], trunk)
    front = {"embed": params["embed"]}
    back = {"final_ln": params["final_ln"]}
    if cfg.tie_embeddings:
        back["embed"] = params["embed"]
    else:
        back["lm_head"] = params["lm_head"]
    lctx = _local_ctx(ctx)

    def embed_fn(front_p, batch):
        return lm.embed_inputs(lctx, cfg, front_p, batch)

    def head_fn(back_p, x):
        x = norm_apply(cfg.norm, back_p["final_ln"], x)
        return lm._head(lctx, cfg, back_p, x)

    bounds = _resolve_bounds(cfg, stages, mode, costs, seq_len)
    return _finish(
        cfg, ctx, seg, mesh=mesh, stages=stages, replicas=replicas,
        bounds=bounds, trunk=trunk, front=front, back=back,
        embed_fn=embed_fn, head_fn=head_fn, microbatches=microbatches,
        mb_size=mb_size, name=cfg.name,
    )


def build_vit_pipeline(params, cfg, ctx: RunCtx, *, stages: int,
                       replicas: int = 1, microbatches: int = 2,
                       mb_size: int = 1, mesh: Mesh | None = None,
                       mode: str = "equal", costs=None) -> StagePipeline:
    """Stage-parallel pipelined ViT forward: images in, class logits out.

    The executable realization of the paper's multi-chip FWS deployment
    (vit-l32 24 blocks over stages) that ``serving/vision.py`` previously
    only chained sequentially chip-by-chip.
    """
    from repro.models import vit

    seg = vit.build_segments(cfg)[0]
    trunk = params["segments"][0]  # vit trunks are always layer-stacked
    front = {k: params[k] for k in ("patch", "cls", "pos")}
    back = {"final_ln": params["final_ln"], "head": params["head"]}
    lctx = _local_ctx(ctx)

    def embed_fn(front_p, batch):
        return vit.embed_images(lctx, cfg, front_p, batch["images"])

    def head_fn(back_p, x):
        return vit.head(lctx, cfg, back_p, x)

    bounds = _resolve_bounds(cfg, stages, mode, costs, cfg.seq_len)
    return _finish(
        cfg, ctx, seg, mesh=mesh, stages=stages, replicas=replicas,
        bounds=bounds, trunk=trunk, front=front, back=back,
        embed_fn=embed_fn, head_fn=head_fn, microbatches=microbatches,
        mb_size=mb_size, name=cfg.name,
    )
