"""Logical-axis sharding rules: per-(arch, mesh, mode) rule tables and
spec-tree -> NamedSharding-tree resolution (MaxText-style)."""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.layers.common import DEFAULT_RULES, ShardingCtx
from repro.models.lm import ArchConfig


def make_rules(cfg: ArchConfig, mesh: Mesh, mode: str = "train",
               batch_size: int | None = None) -> dict:
    """mode: train | prefill | decode."""
    axes = mesh.axis_names
    mdl = mesh.shape["model"] if "model" in axes else 1
    model = "model" if "model" in axes else None
    data_axes = tuple(a for a in ("pod", "data") if a in axes)

    div = lambda n: model if (n % mdl == 0 and n >= mdl) else None
    heads_shardable = cfg.n_heads % mdl == 0 and cfg.n_heads >= mdl

    rules = dict(DEFAULT_RULES)
    rules.update(
        batch=data_axes,
        seq=None,
        embed=None,
        layers=None,
        vocab=div(cfg.vocab_size),
        qkv_fused=div(cfg.n_heads * cfg.hd),
        kv_fused=div(cfg.n_kv_heads * cfg.hd),
        mlp=model,
        heads=model if heads_shardable else None,
        heads_g=None,
        head_dim=None,
        kv_heads=div(cfg.n_kv_heads),
        experts=model if cfg.moe_shard == "ep" else None,
        expert_mlp=model if cfg.moe_shard == "tp" else None,
        exp_group=data_axes,  # grouped MoE dispatch (per DP shard)
        exp_cap=None,
        kv_seq=None,
        cache_seq=None,
        state_heads=model,  # SSM/xLSTM state heads (divisibility-gated)
    )
    if mode == "decode":
        # flash-decoding: shard the resident KV cache's sequence axis over
        # `model` (+`data` when the batch is too small to fill it); q_len
        # is 1 and XLA inserts the partial-softmax combines.
        cache_axes = (
            ("data", "model") if batch_size is not None and batch_size < 16
            else model
        )
        rules.update(kv_seq=model, cache_seq=cache_axes, heads=None,
                     kv_heads=None)
    elif mode == "prefill":
        rules.update(cache_seq=model)
        if not heads_shardable:
            rules.update(kv_seq=model)
    elif not heads_shardable:
        # sequence-parallel attention for head counts not divisible by TP
        rules.update(kv_seq=model)
    return rules


def make_ctx(cfg: ArchConfig, mesh: Mesh | None, mode: str = "train",
             batch_size: int | None = None) -> ShardingCtx:
    if mesh is None:
        return ShardingCtx()
    return ShardingCtx(mesh=mesh, rules=make_rules(cfg, mesh, mode, batch_size))


def _is_spec(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )


def resolve_tree(specs, ctx: ShardingCtx, mesh: Mesh):
    """Logical-axis spec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, ctx.resolve(s)),
        specs,
        is_leaf=_is_spec,
    )


def opt_state_specs(param_specs, cfg: ArchConfig, mesh: Mesh, zero1: bool = True):
    """Adam m/v logical specs: same as params, plus ZeRO-1 style sharding
    of otherwise-replicated leading axes over the data axis (via the
    dedicated 'zero' logical axis name)."""
    if not zero1:
        return param_specs

    def z(spec):
        if not _is_spec(spec):
            return spec
        # replace the first usually-unsharded axis with the 'zero' axis
        out = list(spec)
        for i, a in enumerate(out):
            if a in (None, "embed"):
                out[i] = "zero"
                break
        return tuple(out)

    return jax.tree.map(z, param_specs, is_leaf=_is_spec)


def zero_rules(rules: dict, mesh: Mesh, enabled: bool = True) -> dict:
    r = dict(rules)
    r["zero"] = "data" if (enabled and "data" in mesh.axis_names) else None
    return r


def stage_partition(
    n_layers: int,
    n_chips: int,
    mode: str = "equal",
    costs: list | None = None,
) -> list[tuple[int, int]]:
    """Contiguous split of a layer-stacked trunk over pipeline stages/chips:
    ``[(lo, hi), ...)`` half-open layer ranges.

    ``mode="equal"`` (default) splits by layer count, earlier chips taking
    the remainder (vit-l32 / bert-large: 24 layers, 2 chips ->
    [(0, 12), (12, 24)] — the paper's §5.3 dual-chip FWS deployment).

    ``mode="balanced"`` takes per-layer ``costs`` (e.g. from
    ``distributed.blockwise.serve_layer_costs``) and minimizes the
    bottleneck stage cost over all contiguous partitions (the quantity that
    bounds steady-state pipeline throughput), tie-broken by the sum of
    squared stage costs so equally-bottlenecked cuts prefer flatter loads.
    With no ``costs`` it falls back to the equal split (uniform costs).

    This is the serving-time analogue of the mesh rules above: instead of
    sharding one op over devices, whole blocks are pinned per chip (fully
    weight-stationary — weights never move, activations hop)."""
    if not 1 <= n_chips <= n_layers:
        raise ValueError(f"need 1 <= n_chips ({n_chips}) <= n_layers "
                         f"({n_layers})")
    if mode not in ("equal", "balanced"):
        raise ValueError(f"unknown stage_partition mode {mode!r}")
    if mode == "balanced" and costs is not None:
        if len(costs) != n_layers:
            raise ValueError(
                f"costs has {len(costs)} entries for {n_layers} layers"
            )
        return _balanced_partition([float(c) for c in costs], n_chips)
    base, rem = divmod(n_layers, n_chips)
    bounds = []
    lo = 0
    for c in range(n_chips):
        hi = lo + base + (1 if c < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _balanced_partition(costs: list, k: int) -> list[tuple[int, int]]:
    """Min-bottleneck contiguous k-partition by dynamic programming
    (O(k n^2), exact): every stage gets >= 1 layer."""
    n = len(costs)
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)
    inf = float("inf")
    # best[s][i]: (bottleneck, sum-of-squares) of the first i layers over s
    # stages; cut[s][i] reconstructs the last stage's start
    best = [[(inf, inf)] * (n + 1) for _ in range(k + 1)]
    cut = [[0] * (n + 1) for _ in range(k + 1)]
    best[0][0] = (0.0, 0.0)
    for s in range(1, k + 1):
        for i in range(s, n + 1):
            for j in range(s - 1, i):
                lo_b, lo_sq = best[s - 1][j]
                if lo_b == inf:
                    continue
                c = prefix[i] - prefix[j]
                cand = (max(lo_b, c), lo_sq + c * c)
                if cand < best[s][i]:
                    best[s][i] = cand
                    cut[s][i] = j
    bounds = []
    i = n
    for s in range(k, 0, -1):
        j = cut[s][i]
        bounds.append((j, i))
        i = j
    return bounds[::-1]


def resolve_with_divisibility(specs, shapes, ctx: ShardingCtx, mesh: Mesh):
    """Resolve specs -> NamedSharding, dropping mesh axes whose size does
    not divide the corresponding dim (needed for ZeRO on odd shapes)."""

    from repro.layers.common import DEFAULT_RULES as DR

    def one(spec, sds):
        names = []
        used: set = set()
        for i, ax in enumerate(spec):
            r = ctx.rules.get(ax, DR.get(ax)) if ax else None
            cand = r if isinstance(r, (list, tuple)) else ((r,) if r else ())
            picked = []
            sz = 1
            for a in cand:
                if a is None or a not in mesh.axis_names or a in used:
                    continue
                if sds.shape[i] % (sz * mesh.shape[a]) != 0:
                    continue  # dropped axes must NOT consume `used`
                picked.append(a)
                sz *= mesh.shape[a]
            used.update(picked)
            if not picked:
                names.append(None)
            elif len(picked) == 1 and not isinstance(r, (list, tuple)):
                names.append(picked[0])
            else:
                names.append(tuple(picked))
        return NamedSharding(mesh, P(*names))

    return jax.tree.map(one, specs, shapes, is_leaf=_is_spec)
