"""Roofline-term extraction from compiled XLA artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective wire bytes / link_bw   (per-chip)

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment brief).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    """Sum bytes over every 'dtype[dims]' in a (possibly tuple) shape."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[([0-9,]+)\]<=", line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        return dims[-1] if dims else default
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0  # per-device bytes on ICI (ring model)
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Scan post-partitioning HLO for collective ops and estimate the
    per-device wire traffic with a ring model:
      all-reduce: 2*B*(n-1)/n  (B = result bytes)
      all-gather: B*(n-1)/n    (B = result = full gathered bytes)
      reduce-scatter: B*(n-1)  (B = result = per-shard bytes)
      all-to-all: B*(n-1)/n
      collective-permute: B
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        b = _shape_bytes(m.group(1))
        n = max(_group_size(ls, n_devices), 1)
        if kind == "all-reduce":
            wire = 2.0 * b * (n - 1) / n
        elif kind == "all-gather":
            wire = b * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = b * (n - 1)
        elif kind == "all-to-all":
            wire = b * (n - 1) / n
        else:  # collective-permute
            wire = float(b)
        st.wire_bytes += wire
        k = st.by_kind.setdefault(kind, [0, 0.0])
        k[0] += 1
        k[1] += wire
        st.count += 1
    return st


def roofline_terms(
    cost: dict, collectives: CollectiveStats, n_devices: int
) -> dict:
    """cost: compiled.cost_analysis() (per-device, post-partition)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = collectives.wire_bytes / LINK_BW
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": byts,
        "collective_wire_bytes_per_dev": collectives.wire_bytes,
        "collective_by_kind": {k: {"count": v[0], "wire_bytes": v[1]}
                               for k, v in collectives.by_kind.items()},
        "roofline_fraction": (t_compute / total) if total > 0 else 0.0,
    }


def model_flops(cfg, shape, n_tokens_override=None) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N_active*D for inference (global,
    D = tokens processed per step)."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        d = shape.batch * shape.seq
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.batch * shape.seq
        return 2.0 * n_active * d
    d = shape.batch * 1  # decode: one token per sequence
    return 2.0 * n_active * d


def active_params(cfg) -> float:
    """Active parameter count (MoE counts top_k experts only)."""
    d, ff, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.hd
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    glu = cfg.ffn_kind in ("swiglu", "geglu")
    ffp = d * ff * (3 if glu else 2)
    total = 0.0
    if cfg.family in ("dense", "audio", "vlm"):
        total = L * (attn + ffp)
    elif cfg.family == "moe":
        total = L * (attn + cfg.top_k * ffp + d * cfg.n_experts)
    elif cfg.family == "hybrid":
        di = 2 * d
        gn = cfg.ssm_state
        h = di // cfg.ssm_head_dim
        mamba = d * (2 * di + 2 * gn + h) + di * d
        shared = (2 * d) * d + attn + ffp + d * d
        n_shared = L // max(cfg.shared_attn_every, 1)
        total = L * mamba + n_shared * shared
    elif cfg.family == "ssm":
        di = int(d * 2.0)
        mlstm = d * 2 * di + 3 * di * di + di * d
        slstm = d * 4 * d + 4 * d * (d // cfg.n_heads) + int(d * 4 / 3) * 2 * d + int(d * 4 / 3) * d
        n_s = len(cfg.slstm_at)
        total = (L - n_s) * mlstm + n_s * slstm
    total += v * d * (1 if cfg.tie_embeddings else 2)
    return total
