"""Fault-tolerant training runtime.

Production behaviours implemented (and tested on CPU with tiny configs):
  - resume-from-latest checkpoint with bitwise-reproducible data (the
    pipeline is keyed by step, so kill/restart == uninterrupted run),
  - async checkpointing every N steps with atomic commit + keep-last-k,
  - preemption handling: SIGTERM/SIGINT triggers a final blocking save,
  - straggler/heartbeat monitor: per-step wall times, slow-step events
    logged when a step exceeds ``straggler_factor``x the running median
    (on a real pod this feeds the reschedule/elastic controller),
  - elastic restart: restore() reshards onto whatever mesh the new
    incarnation uses (checkpoint stores global arrays).
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import statistics
import time

import jax

from repro import configs as C
from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import Pipeline, make_batch
from repro.models import lm
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    straggler_factor: float = 3.0
    log_path: str | None = None
    seed: int = 0


class HeartbeatMonitor:
    def __init__(self, factor: float):
        self.factor = factor
        self.times: list[float] = []
        self.slow_steps: list[tuple[int, float]] = []

    def record(self, step: int, dt: float):
        if len(self.times) >= 5:
            med = statistics.median(self.times[-50:])
            if dt > self.factor * med:
                self.slow_steps.append((step, dt))
        self.times.append(dt)


class Trainer:
    def __init__(
        self,
        cfg,
        shape: C.Shape,
        tcfg: TrainerConfig,
        step_fn=None,
        params=None,
        opt_state=None,
        opt_cfg: adamw.AdamWConfig | None = None,
        ctx=None,
    ):
        self.cfg, self.shape, self.tcfg = cfg, shape, tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.monitor = HeartbeatMonitor(tcfg.straggler_factor)
        self._preempted = False
        self.ctx = ctx
        if step_fn is None:
            from repro.layers.common import RunCtx, ShardingCtx

            self.ctx = ctx or RunCtx(shd=ShardingCtx(), dense_attn_max=256)

            def step_fn(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: lm.lm_loss(p, self.cfg, self.ctx, batch)
                )(params)
                p2, s2, met = adamw.apply(self.opt_cfg, params, grads, opt_state)
                met["loss"] = loss
                return p2, s2, met

            step_fn = jax.jit(step_fn)
        self.step_fn = step_fn

        if params is None:
            params, _ = lm.init_model(jax.random.PRNGKey(tcfg.seed), cfg)
        if opt_state is None:
            opt_state = adamw.init(params)
        self.params, self.opt_state = params, opt_state
        self.start_step = 0

        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(
                latest, {"params": self.params, "opt": self.opt_state}
            )
            self.params, self.opt_state = state["params"], state["opt"]
            self.start_step = latest
        self.metrics_log: list[dict] = []

    def _handle_preempt(self, *_):
        self._preempted = True

    def run(self) -> dict:
        old_term = signal.signal(signal.SIGTERM, self._handle_preempt)
        pipe = Pipeline(self.cfg, self.shape, self.tcfg.seed,
                        start_step=self.start_step)
        step = self.start_step
        try:
            while step < self.tcfg.total_steps and not self._preempted:
                got_step, batch = pipe.get()
                assert got_step == step, (got_step, step)
                t0 = time.time()
                self.params, self.opt_state, met = self.step_fn(
                    self.params, self.opt_state, batch
                )
                met = {k: float(v) for k, v in met.items()}
                dt = time.time() - t0
                self.monitor.record(step, dt)
                step += 1
                met.update(step=step, wall_s=dt)
                self.metrics_log.append(met)
                if self.tcfg.log_path:
                    with open(self.tcfg.log_path, "a") as f:
                        f.write(json.dumps(met) + "\n")
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.wait()
                    self.ckpt.save(
                        step, {"params": self.params, "opt": self.opt_state}
                    )
            # final / preemption save
            self.ckpt.wait()
            self.ckpt.save(
                step, {"params": self.params, "opt": self.opt_state},
                blocking=True,
            )
        finally:
            pipe.close()
            signal.signal(signal.SIGTERM, old_term)
        return {
            "final_step": step,
            "preempted": self._preempted,
            "slow_steps": self.monitor.slow_steps,
            "losses": [m["loss"] for m in self.metrics_log],
        }
