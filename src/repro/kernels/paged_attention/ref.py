"""jnp ragged paged-decode reference over the fused page layout.

Gathers only the lanes' page rows out of the pool and then runs the
*exact* legacy decode-branch math from ``layers.attention.attn_apply``
(same einsum strings, op order and dtypes), so the reference is bitwise
the PR 4 legacy decode — on the float path and on the quantized-resident
path (whose fused mirrors decode bitwise, see ``layout``). The Pallas
kernel streams the same pages with an online softmax and per-KV-chunk P
quantization, so kernel-vs-reference is tolerance-equivalent — the same
dense-vs-flash granularity precedent as ``layers.attention._flash_attn``.

Lane ``i`` attends over page slots ``[0, lengths[i])`` of pool row
``rows[i]``; ``lengths[i] == min(pos + 1, W)`` reproduces the legacy
ring-write validity mask (a wrapped ring has all ``W`` slots valid).
``lengths[i] == 0`` (a parked lane) yields a zero output row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.kernels.paged_attention import layout


def ragged_paged_decode_ref(
    q: jax.Array,  # [L, Hkv, G, Dh] — mx path: already MXFP4-fake-quant bf16
    rows: jax.Array,  # int32 [L] pool row per lane
    lengths: jax.Array,  # int32 [L] valid slots per lane, in [0, W]
    *,
    kv: jax.Array | None = None,  # [P, W, 2Hkv, Dh] raw pages (float path)
    quant: dict | None = None,  # fused code mirrors (quantized-resident)
    scale: float,
) -> jax.Array:
    """Returns [L, Hkv, G, Dh]; bf16 on the mx path, ``kv.dtype`` on the
    float path (exactly the legacy decode output dtypes)."""
    hd = q.shape[-1]
    mx = quant is not None
    if mx:
        kvc = jnp.take(quant["kv_codes"], rows, axis=0)
        kd = layout.dequant_k_pages(
            kvc, jnp.take(quant["k_exps"], rows, axis=0), hd
        )
        vd = layout.dequant_v_pages(
            kvc, jnp.take(quant["v_exps"], rows, axis=0), hd
        )
        w = kvc.shape[1]
    else:
        pages = jnp.take(kv, rows, axis=0)  # [L, W, 2Hkv, Dh]
        kd, vd = layout.split_kv(pages)
        w = kv.shape[1]
    valid = jnp.arange(w)[None, :] < lengths[:, None]  # [L, W]
    sc = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q[:, None], kd,
        preferred_element_type=jnp.float32,
    ) * scale
    if mx:
        sc = sc.astype(jnp.bfloat16).astype(jnp.float32)  # systolic round
    sc = jnp.where(valid[:, None, None, None, :], sc, -jnp.inf)
    pr = jax.nn.softmax(sc, axis=-1)
    # zero-length lanes: all-masked softmax is NaN; the legacy decode
    # never sees length 0 (pos >= 0 always validates slot 0), so this
    # guard is an exact no-op on every legacy-reachable input
    pr = jnp.where(valid.any(-1)[:, None, None, None, None], pr, 0.0)
    if mx:
        pr = mxlib.fake_quant(pr)  # P quantized along the key axis
        den = jnp.sum(pr, axis=-1, keepdims=True)
        den = jnp.where(den == 0.0, 1.0, den)
        o = jnp.einsum(
            "bhgqk,bkhd->bqhgd", pr.astype(jnp.bfloat16),
            vd.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
        o = (o / jnp.moveaxis(den, -2, 1)).astype(jnp.bfloat16)
    else:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", pr.astype(vd.dtype), vd)
    return o[:, 0]
