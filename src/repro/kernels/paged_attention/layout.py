"""Fused head-interleaved KV page layout, shared by the ragged paged
flash-decode kernel, its jnp reference, and the attention decode path.

Raw pages interleave K and V per KV head so that *one* HBM->VMEM async
copy per (lane, head, token-chunk) streams both SDPA operands::

    kv [B, W, 2*Hkv, Dh]      K_h = kv[:, :, 2h]   V_h = kv[:, :, 2h+1]

Quantized-resident pools (hybrid / fully-digital MXFP4 SDPA) mirror the
pages in the MXFP4 code domain. Codes are nibble-packed along head_dim
for both operands — a V row's codes sit next to the K row they decode
with, so the same fused copy streams both — while the shared exponents
keep each operand's own blocking (K per row along head_dim, V per
32-slot block along the *key* axis, exactly the PR 4 legacy mirrors)::

    kv_codes [B, W, 2*Hkv, Dpad//2]    uint8  (Dpad = head_dim padded to 32)
    k_exps   [B, W, Hkv, Dpad//32]     int8   per-row head_dim blocks
    v_exps   [B, ceil(W/32), Hkv, Dh]  int8   per 32-slot key block

Dequantizing a bk-token chunk therefore needs one contiguous ``kv_codes``
slice, one ``k_exps`` slice, and at most ``bk//32 + 1`` ``v_exps`` rows.
The quantize calls below are the same ones the legacy split mirrors run
(``layers.attention._quant_cache_full`` / ``_quant_cache_step``), only
repacked — nibble packing is lossless, so the fused mirrors decode
bitwise to the legacy requant-per-step reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib

BLOCK = mxlib.BLOCK


def padded_head_dim(hd: int) -> int:
    return -(-hd // BLOCK) * BLOCK


def fuse_kv(k: jax.Array, v: jax.Array) -> jax.Array:
    """k, v [..., H, D] -> fused [..., 2H, D] (K even / V odd rows)."""
    s = k.shape
    return jnp.stack([k, v], axis=-2).reshape(s[:-2] + (2 * s[-2], s[-1]))


def split_kv(kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused [..., 2H, D] -> (k, v) each [..., H, D]."""
    return kv[..., 0::2, :], kv[..., 1::2, :]


def fused_cache_init(batch: int, w: int, n_kv: int, hd: int,
                     dtype=jnp.bfloat16) -> dict:
    return {"kv": jnp.zeros((batch, w, 2 * n_kv, hd), dtype)}


def fused_quant_init(batch: int, w: int, n_kv: int, hd: int) -> dict:
    """Quantized mirrors of a zero page: zero blocks quantize to zero
    codes (packed byte 0) with the E8M0 floor exponent — matching what
    ``quant_page_full`` would produce on zeros."""
    dpad = padded_head_dim(hd)
    nwb = -(-w // BLOCK)
    return {
        "kv_codes": jnp.zeros((batch, w, 2 * n_kv, dpad // 2), jnp.uint8),
        "k_exps": jnp.full(
            (batch, w, n_kv, dpad // BLOCK), mxlib.E8M0_MIN, jnp.int8
        ),
        "v_exps": jnp.full((batch, nwb, n_kv, hd), mxlib.E8M0_MIN, jnp.int8),
    }


def _pad_d(x: jax.Array, dpad: int) -> jax.Array:
    if x.shape[-1] == dpad:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, dpad - x.shape[-1])]
    return jnp.pad(x, pad)


def quant_page_full(kw: jax.Array, vw: jax.Array) -> dict:
    """Quantize whole cache-shaped K/V pages [B, W, Hkv, Dh]
    (prefill-into-cache) into the fused mirrors. Same quantize calls as
    the legacy mirror fill, repacked into the fused layout."""
    w, hd = kw.shape[1], kw.shape[-1]
    dpad = padded_head_dim(hd)
    kq = mxlib.quantize(kw.astype(jnp.float32))  # codes [B, W, Hkv, Dpad]
    vq = mxlib.quantize_axis(vw.astype(jnp.float32), 1)  # key axis last
    v_codes = _pad_d(jnp.moveaxis(vq.codes[..., :w], -1, 1), dpad)
    return {
        "kv_codes": mxlib.pack_codes(fuse_kv(kq.codes, v_codes)),
        "k_exps": kq.exps,
        "v_exps": jnp.moveaxis(vq.exps, -1, 1),  # [B, ceil(W/32), Hkv, Dh]
    }


def quant_page_step(quant: dict, kv: jax.Array, rows: jax.Array,
                    slot: jax.Array) -> dict:
    """Per-step resident update of the fused mirrors — the fused port of
    ``layers.attention._quant_cache_step``: re-quantize only the written
    K row and the active 32-slot V block, reading raw values back from
    the just-updated fused pool ``kv`` [P, W, 2Hkv, Dh] at pool rows
    ``rows`` (int32 [L], one per decode lane; ``slot`` int32 [L])."""
    w, hd = kv.shape[1], kv.shape[3]
    hkv = kv.shape[2] // 2
    dpad = padded_head_dim(hd)
    even = 2 * jnp.arange(hkv)
    kq = mxlib.quantize(kv[rows, slot][:, 0::2].astype(jnp.float32))
    out = {
        "kv_codes": quant["kv_codes"].at[
            rows[:, None], slot[:, None], even[None, :]
        ].set(mxlib.pack_codes(kq.codes)),
        "k_exps": quant["k_exps"].at[rows, slot].set(kq.exps),
    }
    start = (slot // BLOCK) * BLOCK  # [L]
    idx = start[:, None] + jnp.arange(BLOCK)  # [L, 32]
    blk = kv[rows[:, None], jnp.minimum(idx, w - 1)][..., 1::2, :]
    blk = jnp.where((idx < w)[:, :, None, None], blk, 0)  # partial end block
    vq = mxlib.quantize_axis(blk.astype(jnp.float32), 1)  # [L, Hkv, Dh, 32]
    v_codes = _pad_d(jnp.moveaxis(vq.codes, -1, 1), dpad)  # [L, 32, Hkv, Dpad]
    out["kv_codes"] = out["kv_codes"].at[
        rows[:, None, None], idx[:, :, None], (even + 1)[None, None, :]
    ].set(mxlib.pack_codes(v_codes), mode="drop")
    out["v_exps"] = quant["v_exps"].at[rows, slot // BLOCK].set(
        vq.exps[..., 0]
    )
    return out


def _scale_blocks(codes: jax.Array, exps: jax.Array) -> jax.Array:
    """bf16 code values [..., K] * 2^(e-1) from int8 exps [..., K//32].
    Codes (<= 4 significant bits) times a power of two are exact in bf16,
    so this matches the legacy f32 ``mxlib.dequantize(...).astype(bf16)``
    bitwise."""
    shp = codes.shape
    cb = codes.reshape(shp[:-1] + (shp[-1] // BLOCK, BLOCK))
    scale = mxlib.exp2i(exps.astype(jnp.int32) - 1).astype(jnp.bfloat16)
    return (cb * scale[..., None]).reshape(shp)


def dequant_k_pages(kv_codes: jax.Array, k_exps: jax.Array,
                    hd: int) -> jax.Array:
    """Fused codes [..., W, 2Hkv, Dpad//2] + exps [..., W, Hkv, Dpad//32]
    -> bf16 K pages [..., W, Hkv, Dh]."""
    codes = mxlib.unpack_pairs_bf16(kv_codes[..., 0::2, :])
    return _scale_blocks(codes, k_exps)[..., :hd]


def dequant_v_pages(kv_codes: jax.Array, v_exps: jax.Array,
                    hd: int) -> jax.Array:
    """Fused codes + slot-block-major exps [..., ceil(W/32), Hkv, Dh]
    -> bf16 V pages [..., W, Hkv, Dh]. The shared exponent of slot ``s``
    is row ``s // 32`` of ``v_exps``."""
    codes = mxlib.unpack_pairs_bf16(kv_codes[..., 1::2, :])[..., :hd]
    w = codes.shape[-3]
    scale = mxlib.exp2i(v_exps.astype(jnp.int32) - 1).astype(jnp.bfloat16)
    scale = jnp.repeat(scale, BLOCK, axis=-3)[..., :w, :, :]
    return codes * scale
