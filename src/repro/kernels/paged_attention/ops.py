"""Public dispatch for ragged paged flash-decode + shape-derived knobs.

``ragged_paged_decode`` is what ``layers.attention`` calls from the fused
decode branch: ``use_pallas`` (from ``RunCtx.impl="auto"`` dispatch)
selects the streaming Pallas kernel, otherwise the bitwise jnp reference
runs. ``pick_bk``/``pick_buffers`` derive the chunk width and DMA ring
depth from the page shape — short pages double-buffer, long pages (many
chunks in flight) quad-buffer so compute never waits on HBM.
"""

from __future__ import annotations

import jax

from repro.core import mx as mxlib
from repro.kernels.paged_attention import kernel as pk
from repro.kernels.paged_attention import ref as pref
from repro.obs.profile import profiled_call

BLOCK = mxlib.BLOCK
MAX_BK = 128


def pick_bk(w: int) -> int:
    """Chunk width for a page of ``w`` slots: a multiple of 32 (so V
    slot-blocks tile cleanly) capped at 128; sub-32 pages stream whole."""
    if w < BLOCK:
        return w
    return min(MAX_BK, (w // BLOCK) * BLOCK)


def pick_buffers(w: int, bk: int) -> int:
    """DMA ring depth: quad-buffer once a max-length lane runs >= 8
    chunks (long pages — deeper prefetch hides HBM latency jitter),
    double-buffer otherwise."""
    nchunks = -(-w // bk)
    return 4 if nchunks >= 8 else 2


def ragged_paged_decode(
    q: jax.Array,  # [L, Hkv, G, Dh] (mx path: already fake-quant bf16)
    rows: jax.Array,  # int32 [L] pool row per lane
    lengths: jax.Array,  # int32 [L] valid slots per lane
    *,
    kv: jax.Array | None = None,  # fused raw pages [P, W, 2Hkv, Dh]
    quant: dict | None = None,  # fused code mirrors (quantized-resident)
    scale: float,
    use_pallas: bool = False,
    interpret: bool | None = None,
    bk: int | None = None,
    buffers: int | None = None,
    obs=None,  # repro.obs.Obs: named timing scope + optional wall capture
) -> jax.Array:
    """Returns [L, Hkv, G, Dh]. Exactly one of ``kv`` / ``quant``."""
    if (kv is None) == (quant is None):
        raise ValueError("pass exactly one of kv= (float) or quant= (mx)")
    if not use_pallas:
        return profiled_call(
            "paged_attention.ref", obs,
            lambda: pref.ragged_paged_decode_ref(
                q, rows, lengths, kv=kv, quant=quant, scale=scale
            ),
        )
    w = (kv if quant is None else quant["kv_codes"]).shape[1]
    bk = bk or pick_bk(w)
    buffers = buffers or pick_buffers(w, bk)
    if quant is None:
        return profiled_call(
            "paged_attention", obs,
            lambda: pk.paged_flash_decode(
                q, kv, rows, lengths, scale=scale, bk=bk, buffers=buffers,
                interpret=interpret,
            ),
        )
    return profiled_call(
        "paged_attention.mx", obs,
        lambda: pk.paged_flash_decode_mx(
            q, quant["kv_codes"], quant["k_exps"], quant["v_exps"], rows,
            lengths, scale=scale, bk=bk, buffers=buffers,
            interpret=interpret,
        ),
    )
