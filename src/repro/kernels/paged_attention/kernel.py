"""Ragged paged flash-decode Pallas kernel with multi-buffered DMA.

One grid program per (lane, KV head). The KV pool stays HBM-resident
(``memory_space=ANY``); per-lane page rows and valid lengths arrive as
scalar prefetch, and the program streams its lane's page in ``bk``-token
chunks through a ``buffers``-deep VMEM ring of async copies — chunk
``c + buffers`` starts as soon as slot ``c % buffers``'s tile has been
consumed, so the HBM reads for upcoming chunks overlap the flash
softmax/SV compute of the current one (double buffering at
``buffers=2``, quad at 4). The fused head-interleaved page layout (see
``layout``) lets a single copy per chunk stream both K and V for the
program's head.

Ragged lengths are handled per lane: ``nchunks = ceil(len / bk)`` drives
a dynamic ``fori_loop``, a zero-length lane runs no chunks and stores
zeros, and the tail chunk of a page whose width is not a multiple of
``bk`` is fetched at a clamped offset (re-reading a little overlap) with
the overlap masked out of the online softmax.

The quantized-resident variant streams the MXFP4 code mirrors instead of
raw pages — three copies per chunk (packed codes, K row exponents, the
<= bk//32 + 1 V slot-block exponent rows) — and decodes them to bf16
*inside* the VMEM tile via the ``core/mx`` pair table, so the
HBM-resident cache never leaves the code domain (~4.25 bits/value of KV
traffic instead of 16). V blocks are 32-slot-aligned in the pool, so the
in-tile V dequant is exactly the global quantization; P re-quantizes per
chunk along the key axis, the same granularity precedent as
``layers.attention._flash_attn``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import mx as mxlib
from repro.kernels import default_interpret

BLOCK = mxlib.BLOCK
NEG_INF = -1e30


def _online_update(s, live, v, m_ref, l_ref, acc_ref, mx: bool):
    """One flash-softmax accumulation step. s f32 [G, bk]; v bf16
    [bk, Dh]; live bool [bk]."""
    s = jnp.where(live[None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(live[None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    if mx:
        # per-chunk P quantization + quantized-P running normalizer
        p = mxlib.fake_quant(p)
        pv = jnp.einsum(
            "gk,kd->gd", p.astype(jnp.bfloat16), v,
            preferred_element_type=jnp.float32,
        )
    else:
        pv = jnp.einsum("gk,kd->gd", p.astype(v.dtype), v).astype(
            jnp.float32
        )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new


def _store(o_ref, acc_ref, l_ref):
    den = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
    o_ref[0, 0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


def _decode_kernel(
    rows_ref, lens_ref,  # scalar prefetch: int32 [L]
    q_ref,  # [1, 1, G, Dh] VMEM
    kv_ref,  # [P, W, 2Hkv, Dh] ANY (HBM)
    o_ref,  # [1, 1, G, Dh]
    buf, sem, acc_ref, m_ref, l_ref,
    *, bk: int, buffers: int, scale: float,
):
    li, h = pl.program_id(0), pl.program_id(1)
    row, ln = rows_ref[li], lens_ref[li]
    w = kv_ref.shape[1]
    nchunks = pl.cdiv(ln, bk)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)

    def offset(c):  # clamped tail fetch; overlap masked via `live`
        return jnp.minimum(c * bk, w - bk)

    def dma(slot, c):
        return pltpu.make_async_copy(
            kv_ref.at[row, pl.ds(offset(c), bk), pl.ds(2 * h, 2)],
            buf.at[slot], sem.at[slot],
        )

    for i in range(buffers):  # warm-up: fill the ring
        @pl.when(i < nchunks)
        def _():
            dma(i, i).start()

    qv = q_ref[0, 0]  # [G, Dh]

    def body(c, _):
        slot = jax.lax.rem(c, buffers)
        dma(slot, c).wait()
        k, v = buf[slot][:, 0], buf[slot][:, 1]  # [bk, Dh]
        pos = offset(c) + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
        live = (pos >= c * bk) & (pos < ln)
        s = jnp.einsum(
            "gd,kd->gk", qv, k, preferred_element_type=jnp.float32
        ) * scale
        _online_update(s, live, v, m_ref, l_ref, acc_ref, mx=False)

        @pl.when(c + buffers < nchunks)  # slot just freed: fetch ahead
        def _():
            dma(slot, c + buffers).start()

        return 0

    jax.lax.fori_loop(0, nchunks, body, 0)
    _store(o_ref, acc_ref, l_ref)


def _decode_kernel_mx(
    rows_ref, lens_ref,
    q_ref,  # [1, 1, G, Dh] — already MXFP4-fake-quant bf16
    table_ref,  # [256] uint32 pair table (core/mx.PAIR_TABLE)
    kvc_ref,  # [P, W, 2Hkv, Dpad//2] uint8 ANY
    ke_ref,  # [P, W, Hkv, Dpad//32] int8 ANY
    ve_ref,  # [P, ceil(W/32), Hkv, Dh] int8 ANY
    o_ref,
    cbuf, kebuf, vebuf, csem, kesem, vesem, acc_ref, m_ref, l_ref,
    *, bk: int, buffers: int, scale: float, hd: int,
):
    li, h = pl.program_id(0), pl.program_id(1)
    row, ln = rows_ref[li], lens_ref[li]
    w = kvc_ref.shape[1]
    nbd = ke_ref.shape[-1]
    nwb = ve_ref.shape[1]
    nvb = vebuf.shape[1]  # V exponent rows fetched per chunk
    nchunks = pl.cdiv(ln, bk)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)

    def offset(c):
        return jnp.minimum(c * bk, w - bk)

    def vblock0(c):  # first fetched v_exps row: covers the chunk's blocks
        return jnp.minimum(offset(c) // BLOCK, nwb - nvb)

    def dmas(slot, c):
        offs = offset(c)
        return (
            pltpu.make_async_copy(
                kvc_ref.at[row, pl.ds(offs, bk), pl.ds(2 * h, 2)],
                cbuf.at[slot], csem.at[slot],
            ),
            pltpu.make_async_copy(
                ke_ref.at[row, pl.ds(offs, bk), h],
                kebuf.at[slot], kesem.at[slot],
            ),
            pltpu.make_async_copy(
                ve_ref.at[row, pl.ds(vblock0(c), nvb), h],
                vebuf.at[slot], vesem.at[slot],
            ),
        )

    for i in range(buffers):
        @pl.when(i < nchunks)
        def _():
            for d in dmas(i, i):
                d.start()

    qv = q_ref[0, 0]

    def body(c, _):
        slot = jax.lax.rem(c, buffers)
        for d in dmas(slot, c):
            d.wait()
        offs = offset(c)
        # in-tile pair-table dequant: codes -> bf16, * 2^(e-1) (exact)
        table = table_ref[...]
        kcodes = mxlib.unpack_pairs_bf16(cbuf[slot][:, 0], table)  # [bk, Dpad]
        kscale = mxlib.exp2i(
            kebuf[slot].astype(jnp.int32) - 1
        ).astype(jnp.bfloat16)  # [bk, nbd]
        k = (kcodes.reshape(bk, nbd, BLOCK) * kscale[:, :, None]).reshape(
            bk, nbd * BLOCK
        )[:, :hd]
        vcodes = mxlib.unpack_pairs_bf16(cbuf[slot][:, 1], table)[:, :hd]
        bi = (offs + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)) // BLOCK
        vscale = jnp.take(
            mxlib.exp2i(vebuf[slot].astype(jnp.int32) - 1).astype(
                jnp.bfloat16
            ),
            bi - vblock0(c), axis=0,
        )  # [bk, Dh] — slot-block shared exponents, globally aligned
        v = vcodes * vscale
        pos = offs + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
        live = (pos >= c * bk) & (pos < ln)
        s = jnp.einsum(
            "gd,kd->gk", qv, k, preferred_element_type=jnp.float32
        ) * scale
        s = s.astype(jnp.bfloat16).astype(jnp.float32)  # systolic round
        _online_update(s, live, v, m_ref, l_ref, acc_ref, mx=True)

        @pl.when(c + buffers < nchunks)
        def _():
            for d in dmas(slot, c + buffers):
                d.start()

        return 0

    jax.lax.fori_loop(0, nchunks, body, 0)
    _store(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("scale", "bk", "buffers", "interpret")
)
def paged_flash_decode(
    q: jax.Array,  # [L, Hkv, G, Dh]
    kv: jax.Array,  # [P, W, 2Hkv, Dh] fused pages
    rows: jax.Array,  # int32 [L]
    lengths: jax.Array,  # int32 [L], in [0, W]
    *,
    scale: float,
    bk: int = 128,
    buffers: int = 2,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    L, hkv, g, dh = q.shape
    w = kv.shape[1]
    assert bk <= w, (bk, w)
    scratch = [
        pltpu.VMEM((buffers, bk, 2, dh), kv.dtype),
        pltpu.SemaphoreType.DMA((buffers,)),
        pltpu.VMEM((g, dh), jnp.float32),
        pltpu.VMEM((g,), jnp.float32),
        pltpu.VMEM((g,), jnp.float32),
    ]
    return pl.pallas_call(
        functools.partial(
            _decode_kernel, bk=bk, buffers=buffers, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(L, hkv),
            in_specs=[
                pl.BlockSpec((1, 1, g, dh), lambda l, h, *_: (l, h, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, dh), lambda l, h, *_: (l, h, 0, 0)
            ),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((L, hkv, g, dh), kv.dtype),
        interpret=interpret,
    )(rows, lengths, q, kv)


@functools.partial(
    jax.jit, static_argnames=("scale", "bk", "buffers", "interpret")
)
def paged_flash_decode_mx(
    q: jax.Array,  # [L, Hkv, G, Dh] — already MXFP4-fake-quant bf16
    kv_codes: jax.Array,  # [P, W, 2Hkv, Dpad//2] uint8
    k_exps: jax.Array,  # [P, W, Hkv, Dpad//32] int8
    v_exps: jax.Array,  # [P, ceil(W/32), Hkv, Dh] int8
    rows: jax.Array,
    lengths: jax.Array,
    *,
    scale: float,
    bk: int = 128,
    buffers: int = 2,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    L, hkv, g, dh = q.shape
    w = kv_codes.shape[1]
    assert bk <= w, (bk, w)
    nbd = k_exps.shape[-1]
    nwb = v_exps.shape[1]
    nvb = min(bk // BLOCK + 1, nwb) if bk >= BLOCK else 1
    scratch = [
        pltpu.VMEM((buffers, bk, 2, kv_codes.shape[-1]), jnp.uint8),
        pltpu.VMEM((buffers, bk, nbd), jnp.int8),
        pltpu.VMEM((buffers, nvb, dh), jnp.int8),
        pltpu.SemaphoreType.DMA((buffers,)),
        pltpu.SemaphoreType.DMA((buffers,)),
        pltpu.SemaphoreType.DMA((buffers,)),
        pltpu.VMEM((g, dh), jnp.float32),
        pltpu.VMEM((g,), jnp.float32),
        pltpu.VMEM((g,), jnp.float32),
    ]
    return pl.pallas_call(
        functools.partial(
            _decode_kernel_mx, bk=bk, buffers=buffers, scale=scale, hd=dh
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(L, hkv),
            in_specs=[
                pl.BlockSpec((1, 1, g, dh), lambda l, h, *_: (l, h, 0, 0)),
                pl.BlockSpec((256,), lambda l, h, *_: (0,)),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, dh), lambda l, h, *_: (l, h, 0, 0)
            ),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((L, hkv, g, dh), jnp.bfloat16),
        interpret=interpret,
    )(
        rows, lengths, q, jnp.asarray(mxlib.PAIR_TABLE), kv_codes,
        k_exps, v_exps,
    )
