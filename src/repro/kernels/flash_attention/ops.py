"""Public wrapper: [B, S, H, D] layout, GQA folding, pad/unpad."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.obs.profile import profiled_call


def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    interpret: bool | None = None,  # None -> platform default
    obs=None,  # repro.obs.Obs: named timing scope + optional wall capture
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    o = profiled_call(
        "flash_attention", obs,
        lambda: flash_attention_kernel(
            qf, kf, vf, groups=g, causal=causal, window=window,
            q_offset=q_offset, interpret=interpret,
        ),
    )
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
