"""Naive softmax-attention oracle with causal/window masks and GQA."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * d**-0.5
    qp = jnp.arange(sq) + q_offset
    kp = jnp.arange(sk)
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window > 0:
        m &= kp[None, :] > qp[:, None] - window
    s = jnp.where(m[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
