"""FlashAttention-style Pallas kernel: tiled online softmax with running
max/sum and deferred normalization — the paper's §4.4 digital attention
stage (64-wide pipelined softmax lane + deferred division) mapped onto TPU
VMEM tiling. Supports causal and sliding-window masks and GQA via KV-head
index mapping (no repeated-KV materialization).

Layout: q [BH, Sq, D], k/v [BKV, Sk, D] with BH = B*H, BKV = B*Hkv.
Grid (BH, nq, nk), k innermost; f32 scratch acc/m/l.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import default_interpret

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, nk: int, scale: float, causal: bool, window: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    first_q = qi * bq + q_offset  # absolute position of this q tile's row 0
    first_k = ki * bk

    def compute():
        q_pos = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jax.lax.dot_general(
            q_ref[0].astype(jnp.float32),
            k_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new

    # tile skipping: fully-masked (future, or older-than-window) KV tiles
    live = jnp.bool_(True)
    if causal:
        live &= first_k <= first_q + bq - 1
    if window > 0:
        live &= first_k + bk - 1 > first_q - window
    pl.when(live)(compute)

    @pl.when(ki == nk - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "groups", "bq", "bk", "causal", "window", "q_offset", "interpret"
    ),
)
def flash_attention_kernel(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BKV, Sk, D]
    v: jax.Array,
    *,
    groups: int = 1,  # H // Hkv; BH = BKV * groups
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,  # absolute position of q[0] (decode/prefill chunks)
    interpret: bool | None = None,  # None -> platform default
):
    if interpret is None:
        interpret = default_interpret()
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh == bkv * groups
    bq = min(bq, sq)
    bk = min(bk, sk)
    while sq % bq:
        bq //= 2
    while sk % bk:
        bk //= 2
    nq, nk = sq // bq, sk // bk
    scale = d**-0.5
    return pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, nk=nk, scale=scale, causal=causal,
            window=window, q_offset=q_offset,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda h, qi, ki, g=groups: (h // g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda h, qi, ki, g=groups: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
