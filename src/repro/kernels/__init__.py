# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Platform-derived Pallas ``interpret`` default: compiled lowering on
    TPU (so a TPU run never silently interprets), the interpreter
    everywhere else — the kernels here are Mosaic/TPU kernels
    (``pltpu.VMEM`` scratch), so CPU *and* GPU backends can only run them
    interpreted. Every kernel wrapper and ``RunCtx`` resolves an unset
    ``interpret`` through this."""
    return jax.default_backend() != "tpu"
