"""Jitted public wrapper for the MXFP4 dequant-matmul kernel: handles
arbitrary leading batch dims, non-aligned shapes (pad), and the
CPU-interpret / TPU-compiled switch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mxfp4_matmul.kernel import mxfp4_matmul_kernel
from repro.obs.profile import profiled_call


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pick_bm(m: int, bm: int = 128) -> int:
    """M tile: never shrink below hardware alignment — small/odd M *pads
    up* to the tile instead (ViT's M=197 pads to 2x128, a tiny M=8 pads
    to one 16-row tile). Shrinking toward M's divisors produced degenerate
    tiles (e.g. bm=6) that cannot lower on TPU."""
    return min(bm, _round_up(max(m, 1), 16))


def mxfp4_matmul(
    x: jax.Array,
    codes: jax.Array,
    exps: jax.Array,
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    interpret: bool | None = None,  # None -> platform default
    obs=None,  # repro.obs.Obs: named timing scope + optional wall capture
) -> jax.Array:
    """x [..., K] @ dequant(codes [K//2, N], exps [K//32, N]) -> [..., N]."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = codes.shape[1]
    xm = x.reshape(-1, k)
    m = xm.shape[0]
    bm, bn, bk = block
    bm = pick_bm(m, bm)
    pm = _round_up(m, bm) - m
    if pm:
        xm = jnp.pad(xm, ((0, pm), (0, 0)))
    # N/K tiles shrink to divisors (padding would copy the resident packed
    # weights every call); model dims are 128-multiples on TPU runs.
    bn = min(bn, n)
    bk = min(bk, k)
    while n % bn:
        bn //= 2
    while k % bk or bk % 32:
        bk //= 2
    out = profiled_call(
        "mxfp4_matmul", obs,
        lambda: mxfp4_matmul_kernel(
            xm, codes, exps, bm=bm, bn=bn, bk=max(bk, 32),
            out_dtype=jnp.bfloat16, interpret=interpret,
        ),
    )
    if pm:
        out = out[:m]
    return out.reshape(lead + (n,))
