"""Jitted public wrapper for the MXFP4 dequant-matmul kernel: handles
arbitrary leading batch dims, non-aligned shapes (pad), and the
CPU-interpret / TPU-compiled switch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mxfp4_matmul.kernel import mxfp4_matmul_kernel


def mxfp4_matmul(
    x: jax.Array,
    codes: jax.Array,
    exps: jax.Array,
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    interpret: bool = True,
) -> jax.Array:
    """x [..., K] @ dequant(codes [K//2, N], exps [K//32, N]) -> [..., N]."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = codes.shape[1]
    xm = x.reshape(-1, k)
    m = xm.shape[0]
    bm, bn, bk = block
    pm = (-m) % min(bm, max(m, 1))
    if pm:
        xm = jnp.pad(xm, ((0, pm), (0, 0)))
    # shrink blocks to fit small shapes
    bm = min(bm, xm.shape[0])
    bn = min(bn, n)
    bk = min(bk, k)
    while xm.shape[0] % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while k % bk or bk % 32:
        bk //= 2
    out = mxfp4_matmul_kernel(
        xm, codes, exps, bm=bm, bn=bn, bk=max(bk, 32),
        out_dtype=jnp.bfloat16, interpret=interpret,
    )
    if pm:
        out = out[:m]
    return out.reshape(lead + (n,))
