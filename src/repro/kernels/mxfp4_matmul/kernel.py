"""Fused MXFP4 dequant-matmul Pallas kernel — the TPU analogue of the
CTT-CIM array: weights live in memory as packed 4-bit E2M1 codes + E8M0
scales (4.25 bits/param) and are expanded to f32 only inside the VMEM tile
feeding the MXU. Weights are never materialised at high precision in HBM.

Layout:  x [M, K] bf16;  codes [K//2, N] uint8 (two E2M1 nibbles per byte
along K, even row in the low nibble);  exps [K//32, N] uint8 (biased E8M0).
Grid (nm, nn, nk), K innermost, f32 VMEM accumulator scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import default_interpret


def _decode_tile(codes_u8: jax.Array, exps_u8: jax.Array) -> jax.Array:
    """[bk//2, bn] packed nibbles + [bk//32, bn] biased exps -> f32 [bk, bn].

    Integer-exact E2M1 decode: code2x = (-1)^s * (e==0 ? m : (2+m) << (e-1))
    equals 2x the FP4 value; the E8M0 scale is built by placing the biased
    exponent directly into the IEEE-754 exponent field (bit-exact, unlike
    jnp.exp2 which lowers to exp(x*ln2)).
    """
    kk2, bn = codes_u8.shape
    lo = (codes_u8 & 0x0F).astype(jnp.int32)
    hi = ((codes_u8 >> 4) & 0x0F).astype(jnp.int32)
    nib = jnp.stack([lo, hi], axis=1).reshape(kk2 * 2, bn)
    s = (nib >> 3) & 1
    e = (nib >> 1) & 3
    m = nib & 1
    code2x = jnp.where(e == 0, m, (2 + m) << jnp.maximum(e - 1, 0))
    code2x = jnp.where(s == 1, -code2x, code2x).astype(jnp.float32)
    scale = jax.lax.bitcast_convert_type(
        exps_u8.astype(jnp.int32) << 23, jnp.float32
    )  # [bk//32, bn] == 2^(e-127)
    vals = code2x.reshape(kk2 * 2 // 32, 32, bn) * (0.5 * scale)[:, None, :]
    return vals.reshape(kk2 * 2, bn)


def _kernel(x_ref, c_ref, e_ref, o_ref, acc_ref, *, nk: int, out_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_tile(c_ref[...], e_ref[...])
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w,
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def mxfp4_matmul_kernel(
    x: jax.Array,
    codes: jax.Array,
    exps: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=jnp.bfloat16,
    interpret: bool | None = None,  # None -> platform default
):
    if interpret is None:
        interpret = default_interpret()
    m, k = x.shape
    n = codes.shape[1]
    assert codes.shape == (k // 2, n) and exps.shape == (k // 32, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0
    nm, nn, nk = m // bm, n // bn, k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, out_dtype=out_dtype),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((bk // 32, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, exps)
