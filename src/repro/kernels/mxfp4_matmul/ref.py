"""Pure-jnp oracle for the fused MXFP4 dequant-matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib


def dequant_ref(codes: jax.Array, exps: jax.Array) -> jax.Array:
    """packed uint8 [K//2, N] + biased uint8 [K//32, N] -> f32 [K, N]."""
    c = mxlib.unpack_codes(codes.T).T.astype(jnp.float32)  # [K, N]
    e = mxlib.exps_from_biased(exps)
    scale = mxlib.exp2i(e)  # [K//32, N]
    k, n = c.shape
    return (c.reshape(k // 32, 32, n) * (0.5 * scale)[:, None, :]).reshape(k, n)


def mxfp4_matmul_ref(
    x: jax.Array, codes: jax.Array, exps: jax.Array, out_dtype=jnp.bfloat16
) -> jax.Array:
    w = dequant_ref(codes, exps)
    return jnp.matmul(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(out_dtype)
