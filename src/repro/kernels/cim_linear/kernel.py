"""Analog CTT-CIM forward Pallas kernel: per-32-block integer partial
sums, exponent alignment to the calibrated target E_N under a CM-bit
mirror window (underflow-to-zero below, shift-clamp above), Row-Hist
2-pass merge, and n-bit ADC quantization of each (pass, column) sum.

Inputs are the INT5 signed code domain (codes = 2*fp4 in [-12, 12]) plus
per-block exponents, exactly the paper's eq. (1)-(3) datapath. The block
dot products are exact in f32 (|S| <= 32*144), so the MXU carries the
"analog" accumulation.

Grid (nm, nn); K fully resident per tile (the CTT array is
weight-stationary along K: hidden x hidden macros, paper §4.3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _exp2i(e: jax.Array) -> jax.Array:
    """Exact 2^e via IEEE exponent-field construction (e in [-126, 127])."""
    return jax.lax.bitcast_convert_type(
        (jnp.clip(e, -126, 127) + 127).astype(jnp.int32) << 23, jnp.float32
    )


def _kernel(
    xc_ref, xe_ref, wc_ref, we_ref, cal_ref, o_ref,
    *, nb: int, cm: int, adc_bits: int | None, two_pass: bool,
):
    e_n = cal_ref[0, 0].astype(jnp.int32)
    fs = cal_ref[0, 1]

    def body(b, carry):
        a1, a2 = carry
        xb = xc_ref[:, pl.ds(b * 32, 32)].astype(jnp.float32)
        wb = wc_ref[pl.ds(b * 32, 32), :].astype(jnp.float32)
        s = jax.lax.dot(xb, wb, preferred_element_type=jnp.float32)
        ex = xe_ref[:, pl.ds(b, 1)].astype(jnp.int32)  # [bm, 1]
        ew = we_ref[pl.ds(b, 1), :].astype(jnp.int32)  # [1, bn]
        sh = ex + ew - e_n
        under1 = sh < -cm
        a1 += jnp.where(under1, 0.0, s * _exp2i(jnp.clip(sh, -cm, 0)))
        if two_pass:
            sh2 = sh + cm
            a2 += jnp.where(
                under1 & (sh2 >= -cm), s * _exp2i(jnp.clip(sh2, -cm, 0)), 0.0
            )
        return a1, a2

    zero = jnp.zeros(o_ref.shape, jnp.float32)
    a1, a2 = jax.lax.fori_loop(0, nb, body, (zero, zero))

    def adc(c):
        if adc_bits is None:
            return c
        half = 2.0 ** (adc_bits - 1)
        delta = fs / half
        return jnp.clip(jnp.round(c / delta), -half, half - 1.0) * delta

    y = adc(a1) * _exp2i(e_n) * 0.25
    if two_pass:
        y += adc(a2) * _exp2i(e_n - cm) * 0.25
    o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "cm", "adc_bits", "two_pass", "interpret"),
)
def cim_linear_kernel(
    x_codes: jax.Array,  # int8 [M, K]
    x_exps: jax.Array,  # int8 [M, K//32]
    w_codes: jax.Array,  # int8 [K, N]
    w_exps: jax.Array,  # int8 [K//32, N]
    calib: jax.Array,  # f32 [1, 2] = (E_N, adc_fs)
    *,
    bm: int = 128,
    bn: int = 128,
    cm: int = 3,
    adc_bits: int | None = 10,
    two_pass: bool = True,
    interpret: bool = True,
):
    m, k = x_codes.shape
    n = w_codes.shape[1]
    nb = k // 32
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0 and k % 32 == 0
    return pl.pallas_call(
        functools.partial(
            _kernel, nb=nb, cm=cm, adc_bits=adc_bits, two_pass=two_pass
        ),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, nb), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((nb, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x_codes, x_exps, w_codes, w_exps, calib)
