"""Analog CTT-CIM forward Pallas kernel: per-32-block integer partial
sums, exponent alignment to the calibrated target E_N under a CM-bit
mirror window (underflow-to-zero below, shift-clamp above), Row-Hist
2-pass merge, and n-bit ADC quantization of each (pass, column) sum.

The activation quantize is *fused*: raw activations stream in [bm, bk]
VMEM tiles and are block-quantized to the INT5 signed code domain
(codes = 2*fp4 in [-12, 12]) in-register — exponent extraction and E2M1
rounding by IEEE-754 exponent-field bit manipulation, bitwise the
``core/mx.quantize`` rule — so activation codes/exps never round-trip
HBM. Weights are resident codes + per-block exponents, exactly the
paper's eq. (1)-(3) datapath. The block dot products are exact in f32
(|S| <= 32*144), so the MXU carries the "analog" accumulation.

Grid (nm, nn, nk), K innermost with f32 VMEM pass-1/pass-2 accumulators
(the CTT array is weight-stationary along K; tiling K bounds VMEM at
hidden x hidden macro scale, paper §4.3). The k-grid walks blocks in
ascending order, so accumulation order matches the jnp scan reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import default_interpret


def _exp2i(e: jax.Array) -> jax.Array:
    """Exact 2^e via IEEE exponent-field construction (e in [-126, 127])."""
    return jax.lax.bitcast_convert_type(
        (jnp.clip(e, -126, 127) + 127).astype(jnp.int32) << 23, jnp.float32
    )


def _exp2i_wide(e: jax.Array) -> jax.Array:
    """Two-factor 2^e covering the full block-exponent-sum range
    [-254, 252]: out-of-range negatives underflow to 0 / subnormal powers
    (still exact), positives overflow to inf — both sides behave correctly
    under the linear-domain window compare."""
    h1 = jnp.clip(e // 2, -126, 127)
    return _exp2i(h1) * _exp2i(e - h1)


def _floor_ilog2(x: jax.Array) -> jax.Array:
    """Exact floor(log2(x)) for finite x >= 0 from the exponent field;
    zero/subnormal read as <= -127 (callers clamp)."""
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _quantize_block(xb: jax.Array):
    """One 32-block MXFP4 quantize of [bm, 32] raw f32 activations ->
    (codes f32 [bm, 32] in [-12, 12], block exponent int32 [bm, 1]).
    Bitwise ``core/mx.quantize``: shared exp = floor(log2(amax)) - 2
    clamped to E8M0 (zero blocks land on -127 via the clamp), elements
    rounded ties-to-even on the scaled E2M1 grid and clamped at 6."""
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    ex = jnp.clip(_floor_ilog2(amax) - 2, -127, 127)
    y = xb * _exp2i(-ex)
    ay = jnp.abs(y)
    e = jnp.clip(_floor_ilog2(ay), 0, 2)
    q = jnp.rint(ay * _exp2i(1 - e)) * _exp2i(e - 1)
    q = jnp.minimum(q, 6.0)
    codes = jnp.sign(y) * (2.0 * q)
    return codes, ex


def _kernel(
    x_ref, wc_ref, we_ref, cal_ref, o_ref, a1_ref, a2_ref,
    *, nk: int, nb_tile: int, cm: int, adc_bits: int | None, two_pass: bool,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        a1_ref[...] = jnp.zeros_like(a1_ref)
        a2_ref[...] = jnp.zeros_like(a2_ref)

    e_n = cal_ref[0, 0].astype(jnp.int32)
    fs = cal_ref[0, 1]
    xt = x_ref[...].astype(jnp.float32)  # [bm, bk] raw activations

    lo = 2.0 ** -cm
    lo2 = 2.0 ** -(2 * cm)
    for b in range(nb_tile):  # static unroll over the tile's 32-blocks
        cx, ex = _quantize_block(xt[:, b * 32:(b + 1) * 32])
        wb = wc_ref[pl.ds(b * 32, 32), :].astype(jnp.float32)
        s = jax.lax.dot(cx, wb, preferred_element_type=jnp.float32)
        ew = we_ref[pl.ds(b, 1), :].astype(jnp.int32)  # [1, bn]
        # linear-domain alignment (same identity as core/cim._scan_blocks):
        # uv = 2^(E_X - E_N) * 2^(E_W) is an exact power-of-two product,
        # and 2^clip(sh,-cm,0)*[sh >= -cm] == where(uv < 2^-cm, 0, min(uv, 1))
        uv = _exp2i_wide(ex - e_n) * _exp2i_wide(ew)  # [bm, bn] == 2^sh
        under1 = uv < lo
        a1_ref[...] += s * jnp.where(under1, 0.0, jnp.minimum(uv, 1.0))
        if two_pass:
            # pass-2 target E_N2 = E_N - CM: window sh in [-2cm, -cm)
            a2_ref[...] += s * jnp.where(
                under1 & (uv >= lo2), uv * (2.0 ** cm), 0.0
            )

    @pl.when(ki == nk - 1)
    def _store():
        def adc(c):
            if adc_bits is None:
                return c
            half = 2.0 ** (adc_bits - 1)
            delta = fs / half
            return jnp.clip(jnp.round(c / delta), -half, half - 1.0) * delta

        y = adc(a1_ref[...]) * _exp2i(e_n) * 0.25
        if two_pass:
            y += adc(a2_ref[...]) * _exp2i(e_n - cm) * 0.25
        o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "cm", "adc_bits", "two_pass",
                     "interpret"),
)
def cim_linear_kernel(
    x: jax.Array,  # f32/bf16 [M, K] raw activations (quantize is fused)
    w_codes: jax.Array,  # int8 [K, N]
    w_exps: jax.Array,  # int8 [K//32, N]
    calib: jax.Array,  # f32 [1, 2] = (E_N, adc_fs)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    cm: int = 3,
    adc_bits: int | None = 10,
    two_pass: bool = True,
    interpret: bool | None = None,  # None -> platform default
):
    if interpret is None:
        interpret = default_interpret()
    m, k = x.shape
    n = w_codes.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0
    nm, nn, nk = m // bm, n // bn, k // bk
    return pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, nb_tile=bk // 32, cm=cm, adc_bits=adc_bits,
            two_pass=two_pass,
        ),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((bk // 32, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((1, 2), lambda i, j, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_codes, w_exps, calib)
