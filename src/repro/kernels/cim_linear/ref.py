"""Oracle for the CIM kernel: the pure-jnp analog datapath simulation."""

from __future__ import annotations

import jax

from repro.core import cim as cimlib
from repro.core import mx as mxlib


def cim_linear_ref(
    x: jax.Array,
    w: mxlib.MXW,
    calib: cimlib.LayerCalib,
    cfg: cimlib.CIMConfig | None = None,
) -> jax.Array:
    cfg = cfg or cimlib.CIMConfig()
    y, _ = cimlib.cim_linear(x, w, cfg, calib)
    return y
