"""Jitted wrapper for the fused CIM kernel: raw activations stream in
(the activation quantize runs *inside* the kernel tile — codes/exps never
round-trip HBM) against resident MXFP4 weights + Row-Hist calibration."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cim as cimlib
from repro.core import mx as mxlib
from repro.kernels.cim_linear.kernel import cim_linear_kernel
from repro.kernels.mxfp4_matmul.ops import _round_up, pick_bm
from repro.obs.profile import profiled_call


def cim_linear(
    x: jax.Array,
    w: mxlib.MXW,
    calib: cimlib.LayerCalib,
    *,
    cfg: cimlib.CIMConfig | None = None,
    interpret: bool | None = None,  # None -> platform default
    obs=None,  # repro.obs.Obs: named timing scope + optional wall capture
) -> jax.Array:
    """x [..., K] float -> [..., N] f32 through the analog CIM kernel."""
    cfg = cfg or cimlib.CIMConfig()
    k = w.codes.shape[0]
    n = w.codes.shape[1]
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])[..., :k].astype(jnp.float32)
    m = xm.shape[0]
    bm = pick_bm(m)  # pad M up to the tile, never shrink toward divisors
    pm = _round_up(m, bm) - m
    if pm:
        xm = jnp.pad(xm, ((0, pm), (0, 0)))
    bn, bk = 128, 128
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    bk = min(bk, k)
    while k % bk or bk % 32:
        bk //= 2
    cal = jnp.array(
        [[jnp.asarray(calib.e_n, jnp.float32), calib.adc_fs]], jnp.float32
    )
    out = profiled_call(
        "cim_linear", obs,
        lambda: cim_linear_kernel(
            xm, w.codes, w.exps, cal,
            bm=bm, bn=bn, bk=max(bk, 32), cm=cfg.cm_bits,
            adc_bits=cfg.adc_bits, two_pass=cfg.two_pass,
            interpret=interpret,
        ),
    )
    if pm:
        out = out[:m]
    return out.reshape(lead + (n,))
