"""Jitted wrapper: quantize activations to the INT5 code domain and run
the CIM kernel against resident MXFP4 weights + Row-Hist calibration."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cim as cimlib
from repro.core import mx as mxlib
from repro.kernels.cim_linear.kernel import cim_linear_kernel


def cim_linear(
    x: jax.Array,
    w: mxlib.MXW,
    calib: cimlib.LayerCalib,
    *,
    cfg: cimlib.CIMConfig | None = None,
    interpret: bool = True,
) -> jax.Array:
    """x [..., K] float -> [..., N] f32 through the analog CIM kernel."""
    cfg = cfg or cimlib.CIMConfig()
    k = w.codes.shape[0]
    lead = x.shape[:-1]
    xq = mxlib.quantize(x.reshape(-1, x.shape[-1])[..., :k])
    m = xq.codes.shape[0]
    bm = 128
    pm = (-m) % min(bm, max(m, 1))
    xc, xe = xq.codes, xq.exps
    if pm:
        xc = jnp.pad(xc, ((0, pm), (0, 0)))
        xe = jnp.pad(xe, ((0, pm), (0, 0)))
    bm = min(bm, xc.shape[0])
    while xc.shape[0] % bm:
        bm //= 2
    bn = 128
    n = w.codes.shape[1]
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    cal = jnp.array(
        [[jnp.asarray(calib.e_n, jnp.float32), calib.adc_fs]], jnp.float32
    )
    out = cim_linear_kernel(
        xc, xe, w.codes, w.exps, cal,
        bm=bm, bn=bn, cm=cfg.cm_bits, adc_bits=cfg.adc_bits,
        two_pass=cfg.two_pass, interpret=interpret,
    )
    if pm:
        out = out[:m]
    return out.reshape(lead + (n,))
