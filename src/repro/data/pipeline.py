"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step) — restartable training is
bitwise reproducible (the fault-tolerance tests rely on this), and no two
steps repeat data. A small host-side prefetch thread overlaps batch
synthesis with device execution, mirroring a production input pipeline.
"""

from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.models.lm import ArchConfig


def make_batch(cfg: ArchConfig, shape: C.Shape, seed: int, step: int) -> dict:
    """Pure: (cfg, shape, seed, step) -> train batch dict."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, 0xC1A0])
    )
    b, s = shape.batch, shape.seq
    out: dict = {}
    if cfg.frontend == "audio":
        out["emb"] = rng.standard_normal((b, s, cfg.frontend_dim)).astype(
            np.float32
        )
        # masked-prediction targets: mask ~8% spans
        mask = rng.random((b, s)) < 0.08
        out["labels"] = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
        out["loss_mask"] = mask.astype(np.float32)
        return {k: jnp.asarray(v) for k, v in out.items()}
    # LM: structured synthetic stream (repeated n-grams => learnable)
    base = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    period = 7
    base[:, period:] = np.where(
        rng.random((b, s + 1 - period)) < 0.5,
        base[:, :-period],
        base[:, period:],
    )
    out["ids"] = base[:, :-1]
    out["labels"] = base[:, 1:].astype(np.int32)
    out["loss_mask"] = np.ones((b, s), np.float32)
    if cfg.frontend == "vision":
        out["vis_emb"] = rng.standard_normal(
            (b, cfg.n_vis_tokens, cfg.frontend_dim)
        ).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in out.items()}


class Pipeline:
    """Prefetching iterator over make_batch(step)."""

    def __init__(self, cfg, shape, seed: int = 0, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._next = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._fill, daemon=True)
        self._t.start()

    def _fill(self):
        step = self._next
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.shape, self.seed, step)
            self._q.put((step, batch))
            step += 1

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
