import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh with ShapeDtypeStruct inputs (no allocation), print
memory/cost analysis, and derive the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k --mesh single [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun/
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.distributed import roofline as rl  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    quant: str | None = None,
    verbose: bool = True,
    overrides: dict | None = None,
    blockwise: bool | None = None,
) -> dict:
    if blockwise is None:
        blockwise = not multi_pod  # roofline table is single-pod only
    import dataclasses

    cfg = C.ARCHS[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = C.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    kw = {}
    if quant:
        kw["quant"] = quant
    t0 = time.time()
    with mesh:
        bundle = steps_mod.make_step(cfg, mesh, shape, **kw)
        lowered = bundle.fn.lower(*bundle.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo, n_dev)
    whole = rl.roofline_terms(cost, coll, n_dev)
    # Trip-count-exact roofline from per-block compiles (XLA counts scan
    # bodies once — see distributed/blockwise.py). Single-pod only.
    if blockwise:
        from repro.distributed import blockwise as bw

        terms = bw.analyze_cell(cfg, shape, mesh, quant=quant)
        terms["wholegraph"] = {
            k: whole[k]
            for k in ("t_compute_s", "t_memory_s", "t_collective_s")
        }
    else:
        terms = whole
    mflops = rl.model_flops(cfg, shape)
    hlo_global = terms["hlo_flops_per_dev"] * n_dev
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "quant": kw.get("quant", "default"),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.temp_size_in_bytes
            + mem.argument_size_in_bytes,
            "fits_16GB": (mem.temp_size_in_bytes + mem.argument_size_in_bytes)
            < 16e9,
        },
        "model_flops_global": mflops,
        "useful_flops_ratio": mflops / hlo_global if hlo_global else 0.0,
        **terms,
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {result['mesh']} "
              f"(quant={result['quant']}) ==")
        print(f"  lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory/dev: args {mem.argument_size_in_bytes/2**30:.2f} GiB"
              f" + temp {mem.temp_size_in_bytes/2**30:.2f} GiB"
              f" -> fits16GB={result['memory']['fits_16GB']}")
        print(f"  flops/dev {terms['hlo_flops_per_dev']:.3e}"
              f"  bytes/dev {terms['hlo_bytes_per_dev']:.3e}"
              f"  coll bytes/dev {terms['collective_wire_bytes_per_dev']:.3e}")
        print(f"  t_compute {terms['t_compute_s']*1e3:.2f} ms"
              f"  t_memory {terms['t_memory_s']*1e3:.2f} ms"
              f"  t_coll {terms['t_collective_s']*1e3:.2f} ms"
              f"  dominant={terms['dominant']}"
              f"  MODEL/HLO={result['useful_flops_ratio']:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a, s in C.all_cells():
            meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
            for m in meshes:
                cells.append((a, s, m == "multi"))
    else:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        shapes = (
            [args.shape] if args.shape else C.applicable_shapes(C.ARCHS[args.arch])
        )
        for s in shapes:
            for m in meshes:
                cells.append((args.arch, s, m == "multi"))

    results = []
    for a, s, mp in cells:
        try:
            results.append(run_cell(a, s, mp, quant=args.quant))
        except Exception as e:  # noqa: BLE001 — sweep must survive one bad cell
            traceback.print_exc()
            results.append({
                "arch": a, "shape": s,
                "mesh": "2x16x16" if mp else "16x16",
                "error": f"{type(e).__name__}: {e}",
            })
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    ok = sum(1 for r in results if "error" not in r)
    print(f"\n{ok}/{len(results)} cells compiled successfully")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
