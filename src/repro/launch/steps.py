"""pjit step builders: train / prefill / decode, with sharding trees
resolved from logical-axis rules. Shared by the launcher, the dry-run and
the trainer runtime."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.distributed import sharding as shd
from repro.layers.common import (
    RunCtx,
    _dequant_packed,
    convert_params_mxfp4,
    convert_specs_mxfp4,
    quantize_weights_tree,
)
from repro.models import lm
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class StepBundle:
    fn: Any  # jitted step function
    args: tuple  # ShapeDtypeStruct pytree args to lower with
    ctx: RunCtx


def _replicated(mesh):
    return NamedSharding(mesh, P())


def param_structs(cfg, serve_quant: bool = False):
    """(params ShapeDtypeStruct tree, logical specs tree) — no allocation.
    Specs (string tuples) are captured by side effect since eval_shape
    outputs must be arrays."""
    box = {}

    def only_params():
        p, s = lm.init_model(jax.random.PRNGKey(0), cfg)
        box["specs"] = s
        return p

    pstruct = jax.eval_shape(only_params)
    specs = box["specs"]
    if serve_quant:
        qstruct = jax.eval_shape(convert_params_mxfp4, pstruct)
        qspecs = convert_specs_mxfp4(specs, pstruct)
        return qstruct, qspecs
    return pstruct, specs


def batch_shardings(batch_struct, mesh, ctx):
    ax = {
        "ids": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "loss_mask": ("batch", "seq"),
        "emb": ("batch", "seq", "embed"),
        "vis_emb": ("batch", "seq", "embed"),
        "positions": ("batch", "seq"),
        "pos": (),
    }
    return shd.resolve_with_divisibility(
        {k: ax[k][: v.ndim] for k, v in batch_struct.items()},
        batch_struct, ctx, mesh,
    )


def _data_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def pick_microbatches(mesh, shape: C.Shape, target_tokens: int = 8192) -> int:
    """Gradient-accumulation factor: split the global batch until
    tokens-per-device-per-microbatch <= target (activation memory bound)."""
    dsz = _data_size(mesh)
    k = 1
    while (
        shape.seq * shape.batch // (dsz * k) > target_tokens
        and (shape.batch // (2 * k)) % dsz == 0
        and shape.batch // (2 * k) >= dsz
    ):
        k *= 2
    return k


def param_rules(rules: dict, mesh, fsdp: bool = True) -> dict:
    """Parameter *storage* rules: FSDP — shard the (usually replicated)
    'embed' axis of every weight over the data axes. Compute gathers one
    scanned layer at a time; backward reduce-scatters grads (ZeRO-3)."""
    r = dict(rules)
    if fsdp:
        r["embed"] = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return r


def make_train_step(
    cfg,
    mesh: Mesh,
    shape: C.Shape,
    opt_cfg: adamw.AdamWConfig | None = None,
    quant: str = "mxfp4_ste",
    zero1: bool = True,
    fsdp: bool = True,
    microbatches: int | None = None,
) -> StepBundle:
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    prequant = quant == "mxfp4_ste"
    if prequant:
        quant = "mxfp4_ste_prequant"
    rules = shd.make_rules(cfg, mesh, "train")
    rules = shd.zero_rules(rules, mesh, enabled=zero1)
    ctx = RunCtx(shd=shd.ShardingCtx(mesh=mesh, rules=rules), quant=quant)
    pctx = shd.ShardingCtx(mesh=mesh, rules=param_rules(rules, mesh, fsdp))

    pstruct, specs = param_structs(cfg)
    ostruct = jax.eval_shape(adamw.init, pstruct)
    bstruct = C.input_specs(cfg, shape)
    k_micro = microbatches or pick_microbatches(mesh, shape)

    p_shard = shd.resolve_with_divisibility(specs, pstruct, pctx, mesh)
    ospecs = shd.opt_state_specs(specs, cfg, mesh, zero1=zero1)
    m_shard = shd.resolve_with_divisibility(ospecs, pstruct, pctx, mesh)
    o_shard = adamw.OptState(step=_replicated(mesh), m=m_shard, v=m_shard)
    b_shard = batch_shardings(bstruct, mesh, ctx.shd)
    met_shard = {"loss": _replicated(mesh), "grad_norm": _replicated(mesh),
                 "lr": _replicated(mesh)}

    def loss_fn(p, mb):
        return lm.lm_loss(p, cfg, ctx, mb)

    def train_step(params, opt_state, batch):
        if prequant:
            cparams, qvjp = jax.vjp(quantize_weights_tree, params)
        else:
            cparams, qvjp = params, None
        params, outer_params = cparams, params
        if k_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mb_batch = jax.tree.map(
                lambda x: x.reshape(
                    (k_micro, x.shape[0] // k_micro) + x.shape[1:]
                ),
                batch,
            )

            def micro(carry, mb):
                gs, ls = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gs = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gs, g
                )
                return (gs, ls + l), None

            init = (
                jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ),
                jnp.float32(0.0),
            )
            (grads, loss), _ = jax.lax.scan(micro, init, mb_batch)
            grads = jax.tree.map(lambda g: g / k_micro, grads)
            loss = loss / k_micro
        params = outer_params
        if qvjp is not None:  # STE back through the step-boundary quant
            grads = qvjp(jax.tree.map(lambda g, p: g.astype(p.dtype),
                                      grads, cparams))[0]
        new_params, new_state, metrics = adamw.apply(
            opt_cfg, params, grads, opt_state
        )
        metrics["loss"] = loss
        return new_params, new_state, metrics

    fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, met_shard),
        donate_argnums=(0, 1),
    )
    return StepBundle(fn=fn, args=(pstruct, ostruct, bstruct), ctx=ctx)


def _head_logits(cfg, params, last_hidden):
    if cfg.tie_embeddings:
        return jnp.matmul(
            last_hidden, params["embed"]["emb"].astype(jnp.bfloat16).T
        )
    hp = params["lm_head"]
    if "e_n" in hp:
        # cim_analog-converted head: the analog read-out needs a RunCtx;
        # a silent digital dequant here would mask ADC/alignment error
        raise ValueError(
            "lm_head is cim_analog-converted; compute logits through "
            "models.lm.forward / linear_apply (backend-dispatched), not "
            "_head_logits"
        )
    if "codes" in hp:
        return jnp.matmul(
            last_hidden.astype(jnp.bfloat16),
            _dequant_packed(hp["codes"], hp["exps"]),
        )
    return jnp.matmul(last_hidden, hp["w"].astype(jnp.bfloat16))


def make_prefill_step(
    cfg,
    mesh: Mesh,
    shape: C.Shape,
    quant: str = "mxfp4_wonly",
    with_cache: bool = True,
) -> StepBundle:
    ctx = RunCtx(
        shd=shd.make_ctx(cfg, mesh, "prefill"), quant=quant, decode=False
    )
    pstruct, specs = param_structs(cfg, serve_quant=quant == "mxfp4_wonly")
    bstruct = C.input_specs(cfg, shape)
    p_shard = shd.resolve_with_divisibility(specs, pstruct, ctx.shd, mesh)
    b_shard = batch_shardings(bstruct, mesh, ctx.shd)
    with_c = with_cache and cfg.supports_decode
    cache_len = shape.seq

    def prefill_step(params, batch):
        caches = (
            lm.init_cache(cfg, shape.batch, cache_len) if with_c else None
        )
        hidden, caches = lm.forward(
            params, cfg, ctx, batch, caches=caches, return_hidden=True
        )
        logits = _head_logits(cfg, params, hidden[:, -1])
        ids = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        return (ids, caches) if with_c else (ids, ())

    fn = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
    return StepBundle(fn=fn, args=(pstruct, bstruct), ctx=ctx)


def make_decode_step(
    cfg,
    mesh: Mesh,
    shape: C.Shape,
    quant: str = "mxfp4_wonly",
) -> StepBundle:
    ctx = RunCtx(
        shd=shd.make_ctx(cfg, mesh, "decode", batch_size=shape.batch),
        quant=quant, decode=True
    )
    pstruct, specs = param_structs(cfg, serve_quant=quant == "mxfp4_wonly")
    p_shard = shd.resolve_with_divisibility(specs, pstruct, ctx.shd, mesh)

    cstruct = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.batch, shape.seq)
    )
    cspecs = lm.cache_specs(cfg)
    c_shard = shd.resolve_with_divisibility(cspecs, cstruct, ctx.shd, mesh)
    inp = C.input_specs(cfg, shape)
    ids_in = shd.resolve_with_divisibility(
        ("batch", "seq"), inp["ids"], ctx.shd, mesh
    )
    ids_out = shd.resolve_with_divisibility(
        ("batch",), jax.ShapeDtypeStruct((shape.batch,), jnp.int32),
        ctx.shd, mesh,
    )

    def serve_step(params, caches, ids, pos):
        logits, new_caches = lm.decode_step(params, cfg, ctx, ids, pos, caches)
        next_ids = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        return next_ids.astype(jnp.int32), new_caches

    fn = jax.jit(
        serve_step,
        in_shardings=(p_shard, c_shard, ids_in, _replicated(mesh)),
        out_shardings=(ids_out, c_shard),
        donate_argnums=(1,),
    )
    return StepBundle(
        fn=fn, args=(pstruct, cstruct, inp["ids"], inp["pos"]), ctx=ctx
    )


def make_paged_decode_step(
    cfg,
    mesh: Mesh,
    shape: C.Shape,
    num_slots: int,
    quant: str = "mxfp4_wonly",
    kv_layout: str = "legacy",
) -> StepBundle:
    """Sharded continuous-batching decode step over a slot-paged KV pool.

    ``shape.batch`` is the number of decode *lanes*; the pool holds
    ``num_slots`` request pages plus one scratch row per lane (see
    ``repro.serving.kvcache``). The pool's slot axis carries the logical
    'batch' axis, so it shards exactly like the dense decode cache; lane
    gathers/scatters (``jnp.take`` / ``.at[rows]``) lower to SPMD
    all-gathers under the mesh. Inputs beyond the dense step: ``rows``
    (int32 [lanes] pool-row per lane) and per-lane ``pos`` (int32
    [lanes]).

    ``kv_layout="fused"`` switches the pool to the head-interleaved
    paged layout and decodes in place through the ragged paged
    flash-decode path (``RunCtx.paged_rows``): the step does O(lanes)
    KV writes instead of gathering/scattering full pages.
    """
    import dataclasses as _dc

    from repro.serving import kvcache as kv_mod

    if kv_layout not in ("legacy", "fused"):
        raise ValueError(f"unknown KV layout {kv_layout!r}")
    fused = kv_layout == "fused"
    lanes = shape.batch
    ctx = RunCtx(
        shd=shd.make_ctx(cfg, mesh, "decode", batch_size=lanes),
        quant=quant, decode=True,
    )
    pstruct, specs = param_structs(cfg, serve_quant=quant == "mxfp4_wonly")
    p_shard = shd.resolve_with_divisibility(specs, pstruct, ctx.shd, mesh)

    mx_dig = ctx.hybrid_digital_sdpa  # quantized-resident pool for cim
    cspecs = lm.cache_specs(cfg, mx_digital=mx_dig, fused=fused)
    pool_struct = jax.eval_shape(
        lambda: lm.init_cache(cfg, num_slots + lanes, shape.seq,
                              mx_digital=mx_dig, fused=fused)
    )
    pool_shard = shd.resolve_with_divisibility(
        cspecs, pool_struct, ctx.shd, mesh
    )
    i32 = jnp.int32
    rows_s = jax.ShapeDtypeStruct((lanes,), i32)
    ids_s = jax.ShapeDtypeStruct((lanes, 1), i32)
    pos_s = jax.ShapeDtypeStruct((lanes,), i32)
    ids_out = shd.resolve_with_divisibility(
        ("batch",), jax.ShapeDtypeStruct((lanes,), i32), ctx.shd, mesh
    )

    def paged_step(params, pool, rows, ids, pos):
        if fused:
            dctx = _dc.replace(ctx, paged_rows=rows)
            logits, pool = lm.decode_step(params, cfg, dctx, ids, pos, pool)
        else:
            caches = kv_mod.gather_rows(pool, cspecs, rows)
            logits, caches = lm.decode_step(params, cfg, ctx, ids, pos,
                                            caches)
            pool = kv_mod.scatter_rows(pool, cspecs, rows, caches)
        next_ids = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        return next_ids.astype(i32), pool

    fn = jax.jit(
        paged_step,
        in_shardings=(p_shard, pool_shard, _replicated(mesh),
                      _replicated(mesh), _replicated(mesh)),
        out_shardings=(ids_out, pool_shard),
        donate_argnums=(1,),
    )
    return StepBundle(
        fn=fn, args=(pstruct, pool_struct, rows_s, ids_s, pos_s), ctx=ctx
    )


def make_step(cfg, mesh, shape: C.Shape, **kw) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, **kw)
    return make_decode_step(cfg, mesh, shape, **kw)
