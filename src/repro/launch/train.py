"""Training launcher.

Local (CPU/tests):
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --tiny --steps 20 --seq 64 --batch 4

Production (pod): builds the 16x16 (or 2x16x16) mesh, resolves shardings
from the logical-axis rules, and runs the fault-tolerant trainer with the
pjit train step (FSDP + ZeRO-1 + microbatch accumulation + MXFP4-STE).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs as C
from repro.data.pipeline import Pipeline
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import lm
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--shape", default=None, help="named shape, e.g. train_4k")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU smoke)")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--quant", default="mxfp4_ste")
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    args = ap.parse_args()

    cfg = C.ARCHS[args.arch]
    if args.tiny:
        cfg = C.tiny(cfg)
    shape = (
        C.SHAPES[args.shape]
        if args.shape
        else C.Shape(args.seq, args.batch, "train")
    )

    if args.mesh == "none":
        ctx = RunCtx(shd=ShardingCtx(), quant=args.quant, dense_attn_max=512)
        trainer = Trainer(
            cfg, shape,
            TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt),
            ctx=ctx,
        )
        result = trainer.run()
        print(f"final step {result['final_step']}, "
              f"loss {result['losses'][0]:.3f} -> {result['losses'][-1]:.3f}")
        return

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    with mesh:
        bundle = steps_mod.make_train_step(cfg, mesh, shape, quant=args.quant)
        params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
        opt_state = adamw.init(params)
        pipe = Pipeline(cfg, shape, seed=0)
        for _ in range(args.steps):
            step, batch = pipe.get()
            params, opt_state, metrics = bundle.fn(params, opt_state, batch)
            print(f"step {step}: loss {float(metrics['loss']):.4f}")
        pipe.close()


if __name__ == "__main__":
    main()
