"""Serving launcher: pluggable linear-execution backends.

``--backend mxfp4`` (default): packed MXFP4 weight-only resident weights
(the digital FWS mode). ``--backend cim``: offline Row-Hist calibration +
conversion to resident analog CTT arrays, then an end-to-end *hybrid*
analog/digital decode — static linears on the ``cim_analog`` backend,
SDPA on the digital MXFP4 systolic path. ``--backend float``: bf16.

``--model vit-b16`` / ``--model vit-l32`` serve the vision (encoder)
workloads instead: a single-stream frame engine whose measured stage
traffic drives the twelve-stage FWS pipeline model and is cross-checked
against the paper's Table 7 FPS row (dual-chip 12+12 for vit-l32).

Local smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tiny \
      --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --tiny --backend cim
  PYTHONPATH=src python -m repro.launch.serve --model vit-b16 --backend cim
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import cim as cimlib
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, lm
from repro.models.lm import build_segments


def build_backend(args, cfg, params, batches=None, forward_fn=None,
                  mxfp4_min_n: int = 256):
    """Returns (converted_params, RunCtx) for the requested backend.

    ``batches``/``forward_fn`` select the calibration capture for the cim
    backend (default: LM token batches through ``lm.forward``; the vision
    path passes synthetic images through ``vit.forward``).
    """
    shd = ShardingCtx()
    kw = dict(shd=shd, dense_attn_max=256, impl=args.impl)
    if getattr(args, "interpret", None) is not None:
        kw["interpret"] = args.interpret  # else: platform default
    if args.backend == "float":
        return params, RunCtx(**kw)
    if args.backend == "mxfp4":
        return (
            convert_params_mxfp4(params, min_n=mxfp4_min_n),
            RunCtx(quant="mxfp4_wonly", **kw),
        )
    if args.backend == "cim":
        cim_cfg = cimlib.CIMConfig(
            adc_bits=args.adc_bits, cm_bits=args.cm_bits, two_pass=True
        )
        base_ctx = RunCtx(shd=shd, dense_attn_max=256)
        if batches is None:
            batches = calibrate.calibration_batches(
                cfg, n_batches=args.calib_batches, batch=args.batch,
                seq=args.prompt_len,
            )
        t0 = time.time()
        conv, calibs = calibrate.convert_model_cim(
            params, cfg, base_ctx, batches,
            cim_cfg=cim_cfg, min_n=args.cim_min_n, forward_fn=forward_fn,
        )
        print(f"row-hist calibration: {len(calibs)} static linears -> "
              f"analog arrays in {time.time() - t0:.1f}s")
        return conv, RunCtx(quant="cim", cim=cim_cfg, **kw)
    raise SystemExit(f"unknown --backend {args.backend!r}")


def serve_trace(args, cfg, params, ctx):
    """Continuous-batching serving demo: a burst of staggered synthetic
    requests through ``serving.Engine``, then the schedule mapped onto the
    twelve-stage FWS pipeline model (simulated latency / throughput)."""
    import numpy as np

    from repro.serving import Engine, EngineConfig

    # page budget: full-attention archs take prompt+tokens; sliding-window
    # archs must keep the page inside the narrowest window (no ring wrap)
    windows = [s.attn.window for s in build_segments(cfg)
               if s.attn is not None and s.attn.window > 0]
    page_len = args.prompt_len + args.tokens
    if windows:
        page_len = min(page_len, min(windows))
    prefill_len = max(2, page_len - args.tokens)
    ecfg = EngineConfig(
        lanes=args.lanes, num_slots=args.slots, page_len=page_len,
        prefill_len=prefill_len, policy=args.policy,
        kv_layout=args.kv_layout,
    )
    eng = Engine(params, cfg, ctx, ecfg)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        n = int(rng.integers(2, prefill_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=n).tolist()
        eng.add_request(prompt, max_new=min(args.tokens,
                                            page_len - n))
        # staggered arrivals: a couple of engine steps between submissions
        for _ in range(int(rng.integers(0, 3))):
            eng.step()
    out = eng.run()
    dt = time.time() - t0
    rep = eng.trace_report()
    lat = sorted(rep.request_latency.values())
    n_tok = sum(len(v) for v in out.values())
    print(
        f"{cfg.name} [{args.backend}] serve-trace: {len(out)} requests, "
        f"{n_tok} tokens in {dt:.2f}s wall ({n_tok / dt:.1f} tok/s host)"
    )
    print(
        f"  engine: policy={ecfg.policy} lanes={ecfg.lanes} "
        f"slots={ecfg.num_slots} page={ecfg.page_len} "
        f"slot_util={eng.slot_utilization:.2f}"
    )
    print(
        f"  FWS pipeline model (d={cfg.d_model}): "
        f"{rep.tokens_per_s:.0f} tok/s, steady-state "
        f"{rep.pipeline.steady_state_fps:.0f} batches/s, stage util "
        f"{rep.pipeline.stage_utilization:.2f} "
        f"(analog {rep.pipeline.analog_utilization:.2f} / digital "
        f"{rep.pipeline.digital_utilization:.2f} of busy)"
    )
    print(
        f"  sim latency p50 {lat[len(lat) // 2] * 1e6:.1f}us / max "
        f"{lat[-1] * 1e6:.1f}us"
    )
    for rid in sorted(out)[:4]:
        print(f"  rid {rid}: {out[rid]}")


def serve_vision(args, cfg_full):
    """Vision (encoder) serving: stream frames through the fixed-shape
    jitted forward, then cross-validate the measured stage traffic against
    the paper's Table 7 row on the FWS pipeline model."""
    from repro.hwmodel import specs as S
    from repro.models import vit
    from repro.serving.vision import VisionEngine

    # --tiny keeps the paper's token geometry (patch grid, layers, chips)
    # and shrinks only the width, so the measured traffic still reproduces
    # Table 7; --no-tiny runs the full-size model.
    cfg = C.geometry_tiny_vit(cfg_full) if args.tiny else cfg_full
    params, _ = vit.init_model(jax.random.PRNGKey(0), cfg)
    batches = vit.calibration_images(
        cfg, n_batches=args.calib_batches, batch=args.batch
    )
    params, ctx = build_backend(
        args, cfg, params, batches=batches, forward_fn=vit.forward,
        mxfp4_min_n=args.cim_min_n,
    )
    eng = VisionEngine(params, cfg, ctx)
    frames = jax.random.normal(
        jax.random.PRNGKey(1),
        (args.frames, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    t0 = time.time()
    labels = eng.stream(frames)
    dt = time.time() - t0
    print(
        f"{cfg.name} [{args.backend}] vision-stream: {len(labels)} frames "
        f"({cfg.seq_len} tokens each) in {dt:.2f}s wall "
        f"({len(labels) / dt:.1f} fps host); top-1 = {labels}"
    )
    workload = cfg_full.name if cfg_full.name in S.WORKLOADS else None
    rep = eng.fws_report(workload=workload)
    line = (
        f"  FWS pipeline ({rep.chips} chip(s), d={rep.d_model}, "
        f"N={rep.n_tokens}): {rep.fps:.0f} fps steady-state, "
        f"frame latency {rep.frame_latency_s * 1e6:.1f}us"
    )
    if rep.paper_fps:
        line += (f" | paper Table 7: {rep.paper_fps} fps "
                 f"({100 * rep.fps_error:.2f}% err)")
    print(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch", default="gemma3-1b")
    ap.add_argument("--tiny", action="store_true", default=True,
                    help="reduced smoke config (default)")
    ap.add_argument("--no-tiny", dest="tiny", action="store_false",
                    help="run the full-size architecture")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--backend", default="mxfp4",
                    choices=("float", "mxfp4", "cim"))
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--cim-min-n", type=int, default=32)
    ap.add_argument("--adc-bits", type=int, default=10)
    ap.add_argument("--cm-bits", type=int, default=3)
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="linear engine: auto = compiled Pallas on real "
                         "accelerators, jnp reference on CPU")
    ap.add_argument("--interpret", default=None,
                    type=lambda s: s.lower() in ("1", "true", "yes"),
                    help="force the Pallas interpret flag (default: "
                         "platform-derived — interpret only on CPU)")
    ap.add_argument("--serve-trace", action="store_true",
                    help="continuous-batching engine demo: staggered "
                         "requests + FWS pipeline occupancy report")
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic request count for --serve-trace")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--kv-layout", default="legacy",
                    choices=["legacy", "fused"],
                    help="KV pool layout: legacy split K/V pages, or the "
                         "fused head-interleaved paged layout decoded by "
                         "the ragged paged flash-decode path")
    ap.add_argument("--policy", default="prefill",
                    choices=("prefill", "decode"))
    ap.add_argument("--frames", type=int, default=4,
                    help="synthetic frame count for vision (--model vit-*)")
    args = ap.parse_args()

    if args.arch in C.VISION_ARCHS:
        serve_vision(args, C.VISION_ARCHS[args.arch])
        return

    cfg = C.tiny(C.ARCHS[args.arch]) if args.tiny else C.ARCHS[args.arch]
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode")
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params, ctx = build_backend(args, cfg, params)

    if args.serve_trace:
        serve_trace(args, cfg, params, ctx)
        return

    max_len = args.prompt_len + args.tokens
    caches = lm.init_cache(cfg, args.batch, max_len)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    # head over the last position only (a [B, S, V] logits tensor is
    # wasteful at real vocab sizes), still through the active backend
    # (analog read-out under --backend cim)
    hidden, caches = lm.forward(
        params, cfg, ctx, {"ids": prompt}, caches=caches, return_hidden=True
    )
    logits = lm._head(ctx, cfg, params, hidden[:, -1:])
    ids = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]

    step = jax.jit(lambda p, c, i, pos: lm.decode_step(p, cfg, ctx, i, pos, c))
    t0, outs = time.time(), [ids]
    for t in range(args.tokens - 1):
        logits, caches = step(params, caches, ids,
                              jnp.int32(args.prompt_len + t))
        ids = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
        outs.append(ids)
    dt = time.time() - t0
    print(f"{cfg.name} [{args.backend}]: decoded "
          f"{(args.tokens - 1) * args.batch} tokens "
          f"in {dt:.2f}s; ids[0] = "
          f"{jnp.concatenate(outs, 1)[0].tolist()}")


if __name__ == "__main__":
    main()
