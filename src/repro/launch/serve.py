"""Serving launcher: pluggable linear-execution backends.

``--backend mxfp4`` (default): packed MXFP4 weight-only resident weights
(the digital FWS mode). ``--backend cim``: offline Row-Hist calibration +
conversion to resident analog CTT arrays, then an end-to-end *hybrid*
analog/digital decode — static linears on the ``cim_analog`` backend,
SDPA on the digital MXFP4 systolic path. ``--backend float``: bf16.

``--model vit-b16`` / ``--model vit-l32`` serve the vision (encoder)
workloads instead: a single-stream frame engine whose measured stage
traffic drives the twelve-stage FWS pipeline model and is cross-checked
against the paper's Table 7 FPS row (dual-chip 12+12 for vit-l32).

Telemetry: every run carries a ``repro.obs`` handle — request-trace
spans + pipeline occupancy metrics land in a metrics registry that
``--metrics-out PATH`` dumps as a JSON snapshot plus a Prometheus text
exposition (``PATH`` with a ``.prom`` suffix). ``--profile`` turns on
eager kernel wall-clock capture (named scopes are always on);
``--slo-ttft-ms`` / ``--slo-token-ms`` score the run against latency
targets. ``--log-level`` controls the structured per-step log lines.
``--fidelity`` adds a numerical-fidelity pass over the freshly built
serving tree (per-layer SQNR vs the float reference, MXFP4 clip /
underflow counters, ADC saturation + code-utilization histograms, and
the calibration-drift check) before serving starts; the metrics land in
the same ``--metrics-out`` snapshot.

Local smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tiny \
      --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --tiny --backend cim
  PYTHONPATH=src python -m repro.launch.serve --model vit-b16 --backend cim
  PYTHONPATH=src python -m repro.launch.serve --tiny --serve-trace \
      --metrics-out metrics.json --log-level debug
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro import obs as obs_lib
from repro.core import cim as cimlib
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, lm
from repro.models.lm import build_segments


def conversion_args(args) -> dict:
    """Backend-conversion knobs, read from the CLI in exactly one place.

    Both the LM and vision serve paths call :func:`build_backend`, which
    consumes this dict — so a new conversion flag wired here applies to
    every path at once instead of silently reaching only one of them (the
    ``--cim-min-n`` class of bug)."""
    return dict(
        min_n=args.cim_min_n,  # MXFP4 packing and CIM conversion alike
        adc_bits=args.adc_bits,
        cm_bits=args.cm_bits,
        calib_batches=args.calib_batches,
    )


def build_backend(args, cfg, params, batches=None, forward_fn=None,
                  obs=None):
    """Returns (converted_params, RunCtx) for the requested backend.

    ``batches``/``forward_fn`` select the calibration capture for the cim
    backend (default: LM token batches through ``lm.forward``; the vision
    path passes synthetic images through ``vit.forward``). ``obs`` is the
    telemetry handle threaded into the RunCtx (kernel profiling scopes).
    All conversion knobs come from :func:`conversion_args` — callers no
    longer plumb them per path.
    """
    conv_kw = conversion_args(args)
    shd = ShardingCtx()
    kw = dict(shd=shd, dense_attn_max=256, impl=args.impl, obs=obs)
    if getattr(args, "interpret", None) is not None:
        kw["interpret"] = args.interpret  # else: platform default
    log = obs_lib.get_logger("repro.serve", getattr(args, "log_level", "info"))
    if args.backend == "float":
        return params, RunCtx(**kw)
    if args.backend == "mxfp4":
        return (
            convert_params_mxfp4(params, min_n=conv_kw["min_n"]),
            RunCtx(quant="mxfp4_wonly", **kw),
        )
    if args.backend == "cim":
        cim_cfg = cimlib.CIMConfig(
            adc_bits=conv_kw["adc_bits"], cm_bits=conv_kw["cm_bits"],
            two_pass=True,
        )
        base_ctx = RunCtx(shd=shd, dense_attn_max=256)
        if batches is None:
            batches = calibrate.calibration_batches(
                cfg, n_batches=conv_kw["calib_batches"], batch=args.batch,
                seq=args.prompt_len,
            )
        t0 = time.time()
        conv, calibs = calibrate.convert_model_cim(
            params, cfg, base_ctx, batches,
            cim_cfg=cim_cfg, min_n=conv_kw["min_n"], forward_fn=forward_fn,
        )
        log.info(
            "row-hist calibration: %s",
            obs_lib.kv(linears=len(calibs), wall_s=time.time() - t0),
        )
        return conv, RunCtx(quant="cim", cim=cim_cfg, **kw)
    raise SystemExit(f"unknown --backend {args.backend!r}")


def pipeline_shape(args) -> tuple[int, int] | None:
    """(replicas, stages) from ``--mesh RxS`` / ``--stages``, or None when
    pipelined execution is off."""
    if args.mesh:
        try:
            r, s = args.mesh.lower().split("x")
            shape = (int(r), int(s))
        except ValueError:
            raise SystemExit(f"--mesh wants REPLICASxSTAGES, got "
                             f"{args.mesh!r}")
        if shape[0] < 1 or shape[1] < 1:
            raise SystemExit(f"--mesh axes must be >= 1, got {args.mesh!r}")
        return shape
    if args.stages:
        return (1, args.stages)
    return None


def _mk_obs(args) -> obs_lib.Obs:
    return obs_lib.Obs(profile=args.profile)


def _run_fidelity(args, cfg, fparams, params, ctx, obs, batch,
                  forward_fn=None):
    """``--fidelity``: one numerical-fidelity pass over the serving tree —
    per-layer SQNR against the float tree, quantizer / ADC health
    counters, and the calibration-drift check — published into the run's
    metrics registry before the snapshot is written. For the cim backend
    the reference runs on the digital MXFP4 path (the calibration-matched
    distribution, isolating the analog stack's noise); the other backends
    reference bf16 float, measuring total quantization error."""
    log = obs_lib.get_logger("repro.serve", args.log_level)
    ref_quant = "mxfp4_digital" if args.backend == "cim" else "none"
    t0 = time.time()
    _, rep = obs_lib.run_fidelity_pass(
        fparams, params, cfg, ctx, batch,
        obs=obs, forward_fn=forward_fn,
        ref_quant=ref_quant, quant=ctx.quant, min_n=args.cim_min_n,
    )
    log.info("fidelity: %s", obs_lib.kv(
        layers=len(rep["layers"]),
        output_sqnr_db=rep["sqnr_db"].get("output"),
        drifted=rep["drift"]["n_drifted"],
        wall_s=time.time() - t0,
    ))
    return rep


def _finish_metrics(args, obs: obs_lib.Obs, log) -> None:
    """Score SLOs (when targets given) and write the metrics snapshot."""
    targets = obs_lib.SLOTargets(
        ttft_p99_s=args.slo_ttft_ms / 1e3 if args.slo_ttft_ms else None,
        token_p99_s=args.slo_token_ms / 1e3 if args.slo_token_ms else None,
    )
    slo = None
    if any(v is not None for v in targets.asdict().values()):
        slo = obs_lib.evaluate_slo(obs.finished, targets)
        log.info("slo: %s", obs_lib.kv(
            ok=slo["pass"], **{k: v for k, v in slo["violations"].items()}
        ))
    if args.metrics_out:
        extra = {"requests": obs.request_summary()}
        if slo is not None:
            extra["slo"] = slo
        jp, pp = obs_lib.write_metrics(obs.registry, args.metrics_out,
                                       extra=extra)
        log.info("metrics written: %s", obs_lib.kv(json=jp, prom=pp))


def serve_trace(args, cfg, params, ctx, obs: obs_lib.Obs):
    """Continuous-batching serving demo: a burst of staggered synthetic
    requests through ``serving.Engine``, then the schedule mapped onto the
    twelve-stage FWS pipeline model (simulated latency / throughput)."""
    import numpy as np

    from repro.serving import Engine, EngineConfig

    log = obs_lib.get_logger("repro.serve", args.log_level)

    # page budget: full-attention archs take prompt+tokens; sliding-window
    # archs must keep the page inside the narrowest window (no ring wrap)
    windows = [s.attn.window for s in build_segments(cfg)
               if s.attn is not None and s.attn.window > 0]
    page_len = args.prompt_len + args.tokens
    if windows:
        page_len = min(page_len, min(windows))
    prefill_len = max(2, page_len - args.tokens)
    ecfg = EngineConfig(
        lanes=args.lanes, num_slots=args.slots, page_len=page_len,
        prefill_len=prefill_len, policy=args.policy,
        kv_layout=args.kv_layout, chunk_len=args.chunk_len or None,
        prefix_cache=args.prefix_cache,
    )
    eng = Engine(params, cfg, ctx, ecfg, obs=obs)
    t0 = time.time()
    tokens_done = 0

    def step_logged():
        nonlocal tokens_done
        done = eng.step()
        if not obs.steps:
            return done
        ev = obs.steps[-1]
        live = eng.sched.num_active
        tokens_done += len(ev.rids) if ev.kind == "decode" else 1
        log.debug("step %s", obs_lib.kv(
            n=len(obs.steps), kind=ev.kind, live=live,
            free_slots=eng.kv.num_free, queued=len(eng.sched.waiting),
            wall_ms=ev.wall_s * 1e3,
        ))
        if len(obs.steps) % args.log_every == 0:
            log.info("progress %s", obs_lib.kv(
                step=len(obs.steps), live=live,
                free_slots=eng.kv.num_free, queued=len(eng.sched.waiting),
                tokens=tokens_done,
                tok_s=tokens_done / max(time.time() - t0, 1e-9),
            ))
        return done

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        n = int(rng.integers(2, prefill_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=n).tolist()
        eng.add_request(prompt, max_new=min(args.tokens,
                                            page_len - n))
        # staggered arrivals: a couple of engine steps between submissions
        for _ in range(int(rng.integers(0, 3))):
            step_logged()
    while eng.sched.has_work:
        step_logged()
    out = {rid: list(r.out) for rid, r in eng.requests.items()}
    dt = time.time() - t0
    rep = eng.trace_report()
    rep.publish(obs.registry)
    lat = sorted(rep.request_latency.values())
    n_tok = sum(len(v) for v in out.values())
    log.info(
        "%s [%s] serve-trace done: %s", cfg.name, args.backend,
        obs_lib.kv(requests=len(out), tokens=n_tok, wall_s=dt,
                   tok_s_host=n_tok / dt),
    )
    log.info("engine: %s", obs_lib.kv(
        policy=ecfg.policy, lanes=ecfg.lanes, slots=ecfg.num_slots,
        page=ecfg.page_len, slot_util=eng.slot_utilization,
    ))
    if eng.prefix is not None:
        log.info("prefix-cache: %s", obs_lib.kv(**eng.prefix_stats()))
    log.info("fws-pipeline d=%d: %s", cfg.d_model, obs_lib.kv(
        sim_tok_s=rep.tokens_per_s,
        steady_state_fps=rep.pipeline.steady_state_fps,
        stage_occupancy=rep.pipeline.stage_utilization,
        bubble=rep.pipeline.bubble_fraction,
        fill_latency_us=rep.pipeline.fill_latency_s * 1e6,
        analog_util=rep.pipeline.analog_utilization,
        digital_util=rep.pipeline.digital_utilization,
    ))
    host = obs.request_summary()
    if host["ttft_s"]:
        log.info("host-latency: %s", obs_lib.kv(
            ttft_p50_ms=host["ttft_s"]["p50"] * 1e3,
            ttft_p99_ms=host["ttft_s"]["p99"] * 1e3,
            token_p50_ms=(host["token_latency_s"] or {}).get("p50", 0) * 1e3,
            queue_p99_ms=(host["queue_wait_s"] or {}).get("p99", 0) * 1e3,
        ))
    log.info("sim-latency: %s", obs_lib.kv(
        p50_us=lat[len(lat) // 2] * 1e6, max_us=lat[-1] * 1e6
    ))
    for rid in sorted(out)[:4]:
        log.debug("rid %d: %s", rid, out[rid])
    _finish_metrics(args, obs, log)


def serve_load(args, cfg, params, ctx, obs: obs_lib.Obs):
    """``--arrivals``: trace-driven load harness. Replays a Poisson /
    scripted-burst / recorded-trace arrival process with mixed prompt
    and output lengths (and shared system prompts, what the prefix cache
    deduplicates) through the real engine on the host wall clock, then
    scores SLOs and publishes the load report."""
    import numpy as np

    from repro.serving import Engine, EngineConfig
    from repro.serving import load as load_mod

    log = obs_lib.get_logger("repro.serve", args.log_level)
    windows = [s.attn.window for s in build_segments(cfg)
               if s.attn is not None and s.attn.window > 0]
    page_len = args.prompt_len + args.tokens
    if windows:
        page_len = min(page_len, min(windows))
    prefill_len = max(2, page_len - args.tokens)
    chunk = args.chunk_len or None
    ecfg = EngineConfig(
        lanes=args.lanes, num_slots=args.slots, page_len=page_len,
        prefill_len=prefill_len, policy=args.policy,
        kv_layout=args.kv_layout, chunk_len=chunk,
        prefix_cache=args.prefix_cache,
    )
    eng = Engine(params, cfg, ctx, ecfg, obs=obs)

    kind, val = load_mod.parse_arrivals(args.arrivals)
    rng = np.random.default_rng(0)
    if kind == "trace":
        trace = load_mod.load_trace(val)
    else:
        max_prompt = (page_len if chunk else prefill_len) - 1
        sys_len = max(2, min(2 * chunk if chunk else 4, max_prompt - 2))
        spec = load_mod.WorkloadSpec(
            vocab_size=cfg.vocab_size,
            prompt_len=(2, max(2, max_prompt - sys_len)),
            out_len=(2, max(2, args.tokens)),
            system_len=sys_len, max_prompt=max_prompt,
        )
        reqs = load_mod.synth_requests(spec, args.requests, rng)
        times = (load_mod.poisson_arrivals(val, len(reqs), rng)
                 if kind == "poisson"
                 else load_mod.burst_arrivals(len(reqs), *val))
        trace = load_mod.make_trace(times, reqs)

    # warm the compiled steps on a throwaway request so the replay's
    # arrival clock measures serving, not XLA compilation
    eng.add_request(list(trace[0].prompt), max_new=2)
    eng.run()
    obs.reset()

    log.info("load: replaying %s", obs_lib.kv(
        arrivals=args.arrivals, requests=len(trace),
        chunk_len=chunk or 0, prefix_cache=args.prefix_cache,
        policy=ecfg.policy,
    ))
    res = load_mod.replay(eng, trace)
    rep = load_mod.load_report(eng, wall_s=res["wall_s"])
    eng.trace_report().publish(obs.registry)
    ttft = rep["ttft_s"] or {}
    tokl = rep["token_latency_s"] or {}
    log.info("load done: %s", obs_lib.kv(
        requests=rep["n_requests"], tokens=rep["tokens_generated"],
        wall_s=rep["wall_s"], tok_s=rep["tokens_per_s_wall"],
        ttft_p50_ms=ttft.get("p50", 0) * 1e3,
        ttft_p99_ms=ttft.get("p99", 0) * 1e3,
        token_p50_ms=tokl.get("p50", 0) * 1e3,
        token_p99_ms=tokl.get("p99", 0) * 1e3,
        page_evictions=rep["page_evictions"],
    ))
    if rep["prefix"]:
        log.info("prefix-cache: %s", obs_lib.kv(**rep["prefix"]))
    _finish_metrics(args, obs, log)


def serve_vision(args, cfg_full):
    """Vision (encoder) serving: stream frames through the fixed-shape
    jitted forward, then cross-validate the measured stage traffic against
    the paper's Table 7 row on the FWS pipeline model."""
    from repro.hwmodel import specs as S
    from repro.models import vit
    from repro.serving.vision import VisionEngine

    log = obs_lib.get_logger("repro.serve", args.log_level)
    obs = _mk_obs(args)

    # --tiny keeps the paper's token geometry (patch grid, layers, chips)
    # and shrinks only the width, so the measured traffic still reproduces
    # Table 7; --no-tiny runs the full-size model.
    cfg = C.geometry_tiny_vit(cfg_full) if args.tiny else cfg_full
    fparams, _ = vit.init_model(jax.random.PRNGKey(0), cfg)
    batches = vit.calibration_images(
        cfg, n_batches=args.calib_batches, batch=args.batch
    )
    params, ctx = build_backend(
        args, cfg, fparams, batches=batches, forward_fn=vit.forward,
        obs=obs,
    )
    if args.fidelity:
        _run_fidelity(args, cfg, fparams, params, ctx, obs, batches[0],
                      forward_fn=vit.forward)
    runner = None
    pshape = pipeline_shape(args)
    if pshape is not None:
        from repro.distributed import pipeline_exec as pex

        replicas, stages = pshape
        runner = pex.build_vit_pipeline(
            params, cfg, ctx, stages=stages, replicas=replicas,
            microbatches=args.microbatches,
            mb_size=max(1, -(-args.frames // (replicas *
                                              args.microbatches))),
        )
        log.info("pipelined mesh: %s", obs_lib.kv(
            replicas=replicas, stages=stages,
            microbatches=args.microbatches, capacity=runner.capacity,
            stage_cuts=runner.bounds, trunk_mb=runner.trunk_bytes / 2**20,
        ))
    eng = VisionEngine(params, cfg, ctx, obs=obs, runner=runner)
    frames = jax.random.normal(
        jax.random.PRNGKey(1),
        (args.frames, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    t0 = time.time()
    labels = eng.stream(frames)
    dt = time.time() - t0
    log.info(
        "%s [%s] vision-stream: %s", cfg.name, args.backend,
        obs_lib.kv(frames=len(labels), tokens_each=cfg.seq_len, wall_s=dt,
                   fps_host=len(labels) / dt, top1=labels),
    )
    workload = cfg_full.name if cfg_full.name in S.WORKLOADS else None
    rep = eng.fws_report(workload=workload)
    rep.publish(obs.registry)
    fields = dict(
        chips=rep.chips, d=rep.d_model, n_tokens=rep.n_tokens,
        fps=rep.fps, frame_latency_us=rep.frame_latency_s * 1e6,
        stage_occupancy=rep.pipeline.stage_utilization,
        bubble=rep.pipeline.bubble_fraction,
    )
    if rep.paper_fps:
        fields.update(paper_fps=rep.paper_fps,
                      err_pct=100 * rep.fps_error)
    log.info("fws-pipeline: %s", obs_lib.kv(**fields))
    if runner is not None:
        mrep = eng.measured_report(frames, reps=2)
        mrep.publish(obs.registry)
        log.info("fws-pipeline-measured: %s", obs_lib.kv(
            stages=mrep.n_stages, replicas=mrep.n_replicas,
            step_wall_ms=mrep.step_wall_s * 1e3,
            fps=mrep.throughput_items_per_s,
            steady_fps=mrep.steady_items_per_s,
            bubble=mrep.bubble_fraction,
            fill_ms=mrep.fill_latency_s * 1e3,
        ))
    _finish_metrics(args, obs, log)


def serve_pipelined_lm(args, cfg, params, ctx, obs: obs_lib.Obs,
                       pshape: tuple[int, int]):
    """``--stages``/``--mesh`` LM path: the prefill/scoring forward runs
    stage-parallel on a real device mesh — per-stage resident weights,
    overlapping microbatches — and reports measured pipeline health next
    to the single-device baseline (decode stays on the existing engine)."""
    log = obs_lib.get_logger("repro.serve", args.log_level)
    replicas, stages = pshape
    mb = max(1, -(-args.batch // (replicas * args.microbatches)))
    ids = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    batch = {"ids": ids}
    out, runner = lm.forward_pipelined(
        params, cfg, ctx, batch, stages=stages, replicas=replicas,
        microbatches=args.microbatches, mb_size=mb,
    )
    log.info("pipelined mesh: %s", obs_lib.kv(
        replicas=replicas, stages=stages, microbatches=args.microbatches,
        mb_size=mb, capacity=runner.capacity, stage_cuts=runner.bounds,
        trunk_mb=runner.trunk_bytes / 2**20,
    ))
    rep = runner.measure(batch, reps=2)
    rep.publish(obs.registry)
    # single-device baseline on the same batch, same backend
    base = jax.jit(lambda p, b: lm.forward(p, cfg, ctx, b)[0])
    ref = jax.block_until_ready(base(params, batch))
    t0 = time.perf_counter()
    ref = jax.block_until_ready(base(params, batch))
    base_wall = time.perf_counter() - t0
    match = bool((out == ref).all())
    log.info(
        "%s [%s] pipelined forward: %s", cfg.name, args.backend,
        obs_lib.kv(
            rows=args.batch, tokens=args.batch * args.prompt_len,
            step_wall_ms=rep.step_wall_s * 1e3,
            base_wall_ms=base_wall * 1e3,
            rows_s=rep.throughput_items_per_s,
            steady_rows_s=rep.steady_items_per_s,
            bubble=rep.bubble_fraction,
            fill_ms=rep.fill_latency_s * 1e3,
            parity="bitwise" if match else "diverged",
        ),
    )
    _finish_metrics(args, obs, log)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch", default="gemma3-1b")
    ap.add_argument("--tiny", action="store_true", default=True,
                    help="reduced smoke config (default)")
    ap.add_argument("--no-tiny", dest="tiny", action="store_false",
                    help="run the full-size architecture")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--backend", default="mxfp4",
                    choices=("float", "mxfp4", "cim"))
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--cim-min-n", type=int, default=32)
    ap.add_argument("--adc-bits", type=int, default=10)
    ap.add_argument("--cm-bits", type=int, default=3)
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="linear engine: auto = compiled Pallas on real "
                         "accelerators, jnp reference on CPU")
    ap.add_argument("--interpret", default=None,
                    type=lambda s: s.lower() in ("1", "true", "yes"),
                    help="force the Pallas interpret flag (default: "
                         "platform-derived — interpret only on CPU)")
    ap.add_argument("--serve-trace", action="store_true",
                    help="continuous-batching engine demo: staggered "
                         "requests + FWS pipeline occupancy report")
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic request count for --serve-trace")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--kv-layout", default="legacy",
                    choices=["legacy", "fused"],
                    help="KV pool layout: legacy split K/V pages, or the "
                         "fused head-interleaved paged layout decoded by "
                         "the ragged paged flash-decode path")
    ap.add_argument("--policy", default="prefill",
                    choices=("prefill", "decode", "chunked"),
                    help="admission policy; 'chunked' interleaves prefill "
                         "chunks with decode steps (needs --chunk-len)")
    ap.add_argument("--chunk-len", type=int, default=0,
                    help="chunked prefill: run prompts through a fixed "
                         "[1, chunk_len] step in absolute-position "
                         "windows, lifting the prompt cap from "
                         "prefill_len to page_len (0 = single-shot "
                         "padded prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the KV page pool: "
                         "shared prompt prefixes reuse refcounted, "
                         "content-addressed pages (requires --chunk-len)")
    ap.add_argument("--arrivals", default=None,
                    help="trace-driven load harness: replay this arrival "
                         "process through the engine on the host wall "
                         "clock (poisson:RATE | trace:FILE | "
                         "burst:N:GAP_S) instead of the --serve-trace "
                         "staggered demo")
    ap.add_argument("--frames", type=int, default=4,
                    help="synthetic frame count for vision (--model vit-*)")
    # ------------------------------------------- multi-device FWS pipeline
    ap.add_argument("--stages", type=int, default=0,
                    help="run the forward stage-parallel over this many "
                         "pipeline stages (one device each, weights "
                         "resident per stage); 0 = off")
    ap.add_argument("--microbatches", type=int, default=2,
                    help="overlapping microbatches per pipeline replica "
                         "for --stages/--mesh")
    ap.add_argument("--mesh", default=None,
                    help="REPLICASxSTAGES device mesh for pipelined "
                         "execution (e.g. 2x4: two data-parallel pipeline "
                         "replicas of four stages); overrides --stages. "
                         "On CPU force devices first: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    # ----------------------------------------------------- observability
    ap.add_argument("--metrics-out", default=None,
                    help="write a JSON metrics snapshot here (plus the "
                         "Prometheus text exposition at the same path "
                         "with a .prom suffix)")
    ap.add_argument("--fidelity", action="store_true",
                    help="numerical-fidelity pass after the backend build: "
                         "per-layer SQNR vs the float tree, MXFP4 clip/"
                         "underflow + ADC saturation/code-utilization "
                         "counters, calibration-drift check (eager; "
                         "metrics land in --metrics-out)")
    ap.add_argument("--profile", action="store_true",
                    help="capture eager kernel wall clock (named scopes "
                         "are always on; this adds block_until_ready "
                         "serialization, so it is off by default)")
    ap.add_argument("--log-level", default="info", choices=obs_lib.log.LEVELS
                    if hasattr(obs_lib, "log") else
                    ("debug", "info", "warning", "error"),
                    help="structured log verbosity (debug: one line per "
                         "engine step)")
    ap.add_argument("--log-every", type=int, default=16,
                    help="info-level progress summary every N engine steps")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT p99 SLO target in ms (host wall)")
    ap.add_argument("--slo-token-ms", type=float, default=None,
                    help="per-token latency p99 SLO target in ms")
    args = ap.parse_args()

    log = obs_lib.get_logger("repro.serve", args.log_level)

    if args.arch in C.VISION_ARCHS:
        serve_vision(args, C.VISION_ARCHS[args.arch])
        return

    cfg = C.tiny(C.ARCHS[args.arch]) if args.tiny else C.ARCHS[args.arch]
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode")
    obs = _mk_obs(args)
    fparams, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params, ctx = build_backend(args, cfg, fparams, obs=obs)
    if args.fidelity:
        fb = calibrate.calibration_batches(
            cfg, n_batches=1, batch=args.batch, seq=args.prompt_len
        )[0]
        _run_fidelity(args, cfg, fparams, params, ctx, obs, fb)

    pshape = pipeline_shape(args)
    if pshape is not None:
        serve_pipelined_lm(args, cfg, params, ctx, obs, pshape)
        return

    if args.arrivals:
        serve_load(args, cfg, params, ctx, obs)
        return

    if args.serve_trace:
        serve_trace(args, cfg, params, ctx, obs)
        return

    max_len = args.prompt_len + args.tokens
    caches = lm.init_cache(cfg, args.batch, max_len)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    # head over the last position only (a [B, S, V] logits tensor is
    # wasteful at real vocab sizes), still through the active backend
    # (analog read-out under --backend cim)
    hidden, caches = lm.forward(
        params, cfg, ctx, {"ids": prompt}, caches=caches, return_hidden=True
    )
    logits = lm._head(ctx, cfg, params, hidden[:, -1:])
    ids = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]

    step = jax.jit(lambda p, c, i, pos: lm.decode_step(p, cfg, ctx, i, pos, c))
    t0, outs = time.time(), [ids]
    tok_hist = obs.registry.histogram(
        "serve_token_latency_seconds", "inter-token decode gap (host wall)"
    )
    t_prev = time.perf_counter()
    for t in range(args.tokens - 1):
        logits, caches = step(params, caches, ids,
                              jnp.int32(args.prompt_len + t))
        ids = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
        ids.block_until_ready()
        now = time.perf_counter()
        tok_hist.observe(now - t_prev)
        t_prev = now
        outs.append(ids)
    dt = time.time() - t0
    log.info(
        "%s [%s] greedy decode: %s", cfg.name, args.backend,
        obs_lib.kv(tokens=(args.tokens - 1) * args.batch, wall_s=dt,
                   token_p50_ms=tok_hist.quantile(0.5) * 1e3,
                   ids0=jnp.concatenate(outs, 1)[0].tolist()),
    )
    _finish_metrics(args, obs, log)


if __name__ == "__main__":
    main()
