"""Serving launcher: MXFP4 weight-only resident weights (the FWS mode),
prefill + batched greedy decode.

Local smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tiny \
      --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.launch.steps import _head_logits
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = C.tiny(C.ARCHS[args.arch]) if args.tiny else C.ARCHS[args.arch]
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode")
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params = convert_params_mxfp4(params)
    ctx = RunCtx(shd=ShardingCtx(), quant="mxfp4_wonly", dense_attn_max=256)

    max_len = args.prompt_len + args.tokens
    caches = lm.init_cache(cfg, args.batch, max_len)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    hidden, caches = lm.forward(
        params, cfg, ctx, {"ids": prompt}, caches=caches, return_hidden=True
    )
    ids = jnp.argmax(
        _head_logits(cfg, params, hidden[:, -1]).astype(jnp.float32), -1
    )[:, None]

    step = jax.jit(lambda p, c, i, pos: lm.decode_step(p, cfg, ctx, i, pos, c))
    t0, outs = time.time(), [ids]
    for t in range(args.tokens - 1):
        logits, caches = step(params, caches, ids,
                              jnp.int32(args.prompt_len + t))
        ids = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
        outs.append(ids)
    dt = time.time() - t0
    print(f"{cfg.name}: decoded {(args.tokens - 1) * args.batch} tokens "
          f"in {dt:.2f}s; ids[0] = "
          f"{jnp.concatenate(outs, 1)[0].tolist()}")


if __name__ == "__main__":
    main()
