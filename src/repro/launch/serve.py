"""Serving launcher: pluggable linear-execution backends.

``--backend mxfp4`` (default): packed MXFP4 weight-only resident weights
(the digital FWS mode). ``--backend cim``: offline Row-Hist calibration +
conversion to resident analog CTT arrays, then an end-to-end *hybrid*
analog/digital decode — static linears on the ``cim_analog`` backend,
SDPA on the digital MXFP4 systolic path. ``--backend float``: bf16.

Local smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tiny \
      --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --tiny --backend cim
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import cim as cimlib
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, lm


def build_backend(args, cfg, params):
    """Returns (converted_params, RunCtx) for the requested backend."""
    shd = ShardingCtx()
    kw = dict(shd=shd, dense_attn_max=256, impl=args.impl,
              interpret=args.interpret)
    if args.backend == "float":
        return params, RunCtx(**kw)
    if args.backend == "mxfp4":
        return (
            convert_params_mxfp4(params),
            RunCtx(quant="mxfp4_wonly", **kw),
        )
    if args.backend == "cim":
        cim_cfg = cimlib.CIMConfig(
            adc_bits=args.adc_bits, cm_bits=args.cm_bits, two_pass=True
        )
        base_ctx = RunCtx(shd=shd, dense_attn_max=256)
        batches = calibrate.calibration_batches(
            cfg, n_batches=args.calib_batches, batch=args.batch,
            seq=args.prompt_len,
        )
        t0 = time.time()
        conv, calibs = calibrate.convert_model_cim(
            params, cfg, base_ctx, batches,
            cim_cfg=cim_cfg, min_n=args.cim_min_n,
        )
        print(f"row-hist calibration: {len(calibs)} static linears -> "
              f"analog arrays in {time.time() - t0:.1f}s")
        return conv, RunCtx(quant="cim", cim=cim_cfg, **kw)
    raise SystemExit(f"unknown --backend {args.backend!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--backend", default="mxfp4",
                    choices=("float", "mxfp4", "cim"))
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--cim-min-n", type=int, default=32)
    ap.add_argument("--adc-bits", type=int, default=10)
    ap.add_argument("--cm-bits", type=int, default=3)
    ap.add_argument("--impl", default="jnp", choices=("jnp", "pallas"),
                    help="pure-jnp reference or Pallas kernels")
    ap.add_argument("--no-interpret", dest="interpret", action="store_false",
                    default=True,
                    help="compile Pallas kernels instead of interpreting "
                         "(real TPU runs; requires --impl pallas)")
    args = ap.parse_args()

    cfg = C.tiny(C.ARCHS[args.arch]) if args.tiny else C.ARCHS[args.arch]
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode")
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params, ctx = build_backend(args, cfg, params)

    max_len = args.prompt_len + args.tokens
    caches = lm.init_cache(cfg, args.batch, max_len)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    # head over the last position only (a [B, S, V] logits tensor is
    # wasteful at real vocab sizes), still through the active backend
    # (analog read-out under --backend cim)
    hidden, caches = lm.forward(
        params, cfg, ctx, {"ids": prompt}, caches=caches, return_hidden=True
    )
    logits = lm._head(ctx, cfg, params, hidden[:, -1:])
    ids = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]

    step = jax.jit(lambda p, c, i, pos: lm.decode_step(p, cfg, ctx, i, pos, c))
    t0, outs = time.time(), [ids]
    for t in range(args.tokens - 1):
        logits, caches = step(params, caches, ids,
                              jnp.int32(args.prompt_len + t))
        ids = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
        outs.append(ids)
    dt = time.time() - t0
    print(f"{cfg.name} [{args.backend}]: decoded "
          f"{(args.tokens - 1) * args.batch} tokens "
          f"in {dt:.2f}s; ids[0] = "
          f"{jnp.concatenate(outs, 1)[0].tolist()}")


if __name__ == "__main__":
    main()
