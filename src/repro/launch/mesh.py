"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis (the paper's chip-to-chip pipeline / cross-pod data axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests only."""
    return jax.make_mesh((data, model), ("data", "model"))
