"""AdamW with global-norm clipping and warmup-cosine schedule.
Self-contained (no optax): states are plain pytrees so they inherit the
framework's sharding/checkpoint machinery, including ZeRO-1 state sharding
(see distributed.sharding.opt_state_specs)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array  # [] int32
    m: dict
    v: dict


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init(params) -> OptState:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply(
    cfg: AdamWConfig, params, grads, state: OptState
) -> tuple[dict, OptState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (step_ + wd)).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
