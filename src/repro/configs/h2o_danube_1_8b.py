"""h2o-danube-1.8b [arXiv:2401.16818]: llama+mistral mix with SWA."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000,
    attn_pattern="swa", window=4096, rope_theta=1e4,
    ffn_kind="swiglu", norm="rmsnorm",
    subquadratic=True,  # sliding window => bounded KV; runs long_500k
)
