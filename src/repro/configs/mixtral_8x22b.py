"""mixtral-8x22b [arXiv:2401.04088]: 8 experts top-2, SWA."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    n_experts=8, top_k=2, moe_shard="tp",  # 8 experts < 16-way model axis
    attn_pattern="swa", window=4096, rope_theta=1e6,
    ffn_kind="swiglu", norm="rmsnorm",
    subquadratic=True,
)
