"""vit-b16 [ViT-B/16, 224px]: the paper's single-chip headline workload
(Table 7: 41,269 FPS on the Base system; N = 14*14 + 1 = 197 tokens,
matching ``hwmodel.specs.WORKLOADS['vit-b16']``)."""
from repro.models.vit import ViTConfig

CONFIG = ViTConfig(
    name="vit-b16",
    image_size=224, patch_size=16,
    n_layers=12, d_model=768, n_heads=12, d_ff=3072,
    n_classes=1000,
    ffn_kind="gelu", norm="layernorm", use_bias=True,
)
