"""hubert-xlarge [arXiv:2106.07447]: encoder-only audio transformer.
Modality frontend is a STUB: inputs are precomputed frame embeddings."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    causal=False, attn_pattern="full",
    ffn_kind="gelu", norm="layernorm", use_bias=True,
    frontend="audio", frontend_dim=512,
    supports_decode=False,  # encoder-only: decode_32k & long_500k skipped
    subquadratic=False,
)
