"""Architecture registry, input-shape registry, reduced (smoke) configs,
and input-spec builders for every (arch x shape) cell."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.lm import ArchConfig
from repro.models.vit import ViTConfig

from repro.configs import (  # noqa: E402
    gemma3_1b,
    h2o_danube_1_8b,
    hubert_xlarge,
    mixtral_8x22b,
    nemotron4_15b,
    qwen2_vl_7b,
    qwen3_moe_235b,
    starcoder2_7b,
    vit_b16,
    vit_l32,
    xlstm_125m,
    zamba2_1_2b,
)

ARCHS: dict[str, ArchConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        h2o_danube_1_8b,
        starcoder2_7b,
        gemma3_1b,
        nemotron4_15b,
        mixtral_8x22b,
        qwen3_moe_235b,
        hubert_xlarge,
        zamba2_1_2b,
        xlstm_125m,
        qwen2_vl_7b,
    )
}

# Executable vision (encoder) workloads — the paper's own evaluation
# family, first-class next to the LM archs. ``tiny_vit`` shrinks width for
# CPU smoke; ``geometry_tiny_vit`` keeps the paper's token geometry
# (patch grid, CLS, layer count, chip split) while shrinking width, so the
# serving engine's *measured stage traffic* still reproduces Table 7.
VISION_ARCHS: dict[str, ViTConfig] = {
    c.CONFIG.name: c.CONFIG for c in (vit_b16, vit_l32)
}


def tiny_vit(cfg: ViTConfig) -> ViTConfig:
    """Reduced vision config for CPU smoke tests. patch_dim stays
    32-aligned (8*8*3 = 192) so the patch embedding remains
    analog-eligible, exercising the full hybrid conversion path."""
    return dataclasses.replace(
        cfg, image_size=32, patch_size=8, n_layers=2, d_model=64,
        n_heads=4, head_dim=16, d_ff=96, n_classes=32, chips=1,
    )


def geometry_tiny_vit(cfg: ViTConfig) -> ViTConfig:
    """Width-reduced but geometry-true: same image/patch grid (so the same
    token count N), same layer count and chip split as the full workload —
    the shape the FWS pipeline bills — with tiny d_model/d_ff so the
    executable forward is CPU-affordable."""
    return dataclasses.replace(
        cfg, d_model=64, n_heads=4, head_dim=16, d_ff=96, n_classes=32,
    )


# Paper's own short-sequence encoder workloads (hwmodel / accuracy benches).
PAPER_ARCHS: dict[str, ArchConfig] = {
    "vit-b16": ArchConfig(
        name="vit-b16", family="audio", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=1000, causal=False,
        ffn_kind="gelu", norm="layernorm", use_bias=True, frontend="audio",
        frontend_dim=768, supports_decode=False,
    ),
    "vit-l32": ArchConfig(
        name="vit-l32", family="audio", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab_size=1000, causal=False,
        ffn_kind="gelu", norm="layernorm", use_bias=True, frontend="audio",
        frontend_dim=768, supports_decode=False,
    ),
    "bert-base": ArchConfig(
        name="bert-base", family="audio", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=30522, causal=False,
        ffn_kind="gelu", norm="layernorm", use_bias=True, frontend="audio",
        frontend_dim=768, supports_decode=False,
    ),
}


class Shape(NamedTuple):
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k": Shape(4096, 256, "train"),
    "prefill_32k": Shape(32768, 32, "prefill"),
    "decode_32k": Shape(32768, 128, "decode"),
    "long_500k": Shape(524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Which of the 4 assigned shapes run for this arch (DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.supports_decode:
        out.append("decode_32k")
        if cfg.subquadratic:
            out.append("long_500k")
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, c in ARCHS.items() for s in applicable_shapes(c)]


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a, c in ARCHS.items():
        for s in SHAPES:
            if s in applicable_shapes(c):
                continue
            why = (
                "encoder-only (no decode step)"
                if not c.supports_decode
                else "pure full attention (long_500k needs sub-quadratic)"
            )
            out.append((a, s, why))
    return out


# ----------------------------------------------------------- reduced cfgs

def tiny(cfg: ArchConfig, seq: int = 32) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: keeps the block
    pattern representative (>=1 global layer, >=1 shared block, >=1 sLSTM,
    few experts) but shrinks all dims."""
    over: dict = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=128,
        window=min(cfg.window, 16),
    )
    if cfg.attn_pattern == "local_global":
        over.update(n_layers=2 * (cfg.lg_ratio + 1), lg_ratio=cfg.lg_ratio)
    elif cfg.family == "hybrid":
        over.update(n_layers=5, shared_attn_every=2, ssm_state=16,
                    ssm_head_dim=16)
    elif cfg.family == "ssm":
        over.update(n_layers=4, slstm_at=(1,))
    else:
        over.update(n_layers=2)
    if cfg.n_experts:
        over.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.frontend != "none":
        over.update(frontend_dim=24, n_vis_tokens=8)
    return dataclasses.replace(cfg, **over)


# ------------------------------------------------------------ input specs

def input_specs(cfg: ArchConfig, shape: str | Shape, multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation).

    train/prefill -> kwargs for train_step/prefill_step;
    decode        -> kwargs for serve_step (ids, pos, caches built
                     separately via models.lm.init_cache under eval_shape).
    """
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    b, s = sh.batch, sh.seq
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    if sh.kind == "decode":
        return {"ids": sds((b, 1), i32), "pos": sds((), i32)}
    batch: dict = {}
    if cfg.frontend == "audio":
        batch["emb"] = sds((b, s, cfg.frontend_dim), f32)
    else:
        batch["ids"] = sds((b, s), i32)
    if cfg.frontend == "vision":
        batch["vis_emb"] = sds((b, cfg.n_vis_tokens, cfg.frontend_dim), f32)
    if sh.kind == "train":
        batch["labels"] = sds((b, s), i32)
        batch["loss_mask"] = sds((b, s), f32)
    return batch


def concrete_inputs(cfg: ArchConfig, shape: Shape, seed: int = 0):
    """Small concrete arrays matching input_specs (smoke tests)."""
    rng = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        rng, sub = jax.random.split(rng)
        if v.dtype == jnp.int32:
            hi = cfg.vocab_size if k in ("ids", "labels") else 2**30
            out[k] = jax.random.randint(sub, v.shape, 0, min(hi, 2**30), jnp.int32)
        else:
            out[k] = jax.random.normal(sub, v.shape, v.dtype)
    if "loss_mask" in out:
        out["loss_mask"] = jnp.ones(specs["loss_mask"].shape, jnp.float32)
    return out
