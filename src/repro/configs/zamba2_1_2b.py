"""zamba2-1.2b [arXiv:2411.15242]: Mamba2 backbone + shared attention."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, shared_attn_every=6,
    attn_pattern="full", rope_theta=1e4,
    ffn_kind="gelu", norm="rmsnorm",
    subquadratic=True,  # SSM state recurrence; runs long_500k
)
