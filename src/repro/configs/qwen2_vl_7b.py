"""qwen2-vl-7b [arXiv:2409.12191]: M-RoPE decoder; vision frontend STUB
(input_specs provides precomputed patch embeddings)."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    attn_pattern="full", rope_theta=1e6, mrope=True,
    ffn_kind="swiglu", norm="rmsnorm",
    frontend="vision", frontend_dim=1176, n_vis_tokens=64,
    subquadratic=False,  # full attention => long_500k skipped
)
