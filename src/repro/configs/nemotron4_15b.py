"""nemotron-4-15b [arXiv:2402.16819]: GQA, squared-ReLU MLP."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    attn_pattern="full", rope_theta=1e4,
    ffn_kind="relu2", norm="layernorm",
    subquadratic=False,  # full attention => long_500k skipped
)
