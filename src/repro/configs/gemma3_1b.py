"""gemma3-1b [hf:google/gemma-3-1b-pt]: 5:1 local:global, kv=1, 262k vocab."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    attn_pattern="local_global", lg_ratio=5, window=512,
    rope_theta=1e4, rope_theta_global=1e6, qk_norm=True,
    ffn_kind="geglu", norm="rmsnorm", tie_embeddings=True,
    subquadratic=True,  # 5:1 local; the few global layers have kv=1
)
