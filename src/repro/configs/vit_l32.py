"""vit-l32 [ViT-L/32, 384px]: the paper's dual-chip headline workload
(Table 7: 58,275 FPS on two Large chips; N = 12*12 + 1 = 145 tokens,
24 encoder blocks statically split 12+12 across the two chips, matching
``hwmodel.specs.WORKLOADS['vit-l32']``)."""
from repro.models.vit import ViTConfig

CONFIG = ViTConfig(
    name="vit-l32",
    image_size=384, patch_size=32,
    n_layers=24, d_model=1024, n_heads=16, d_ff=4096,
    n_classes=1000,
    ffn_kind="gelu", norm="layernorm", use_bias=True,
    chips=2,
)
