"""xlstm-125m [arXiv:2405.04517]: sLSTM + mLSTM blocks (d_ff=0: the
blocks carry their own projections)."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_at=(3, 9),  # ~[5:1] mLSTM:sLSTM mix
    norm="rmsnorm", tie_embeddings=True,
    subquadratic=True,  # recurrent state; runs long_500k
)
