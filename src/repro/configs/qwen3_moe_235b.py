"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family]: 128e top-8."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    n_experts=128, top_k=8, moe_shard="ep",  # 8 experts per device @ TP16
    attn_pattern="full", rope_theta=1e6, qk_norm=True,
    ffn_kind="swiglu", norm="rmsnorm",
    subquadratic=False,  # full attention => long_500k skipped
)
