"""starcoder2-7b [arXiv:2402.19173]: GQA + RoPE, LN + bias, GELU MLP."""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152,
    attn_pattern="full", rope_theta=1e5,
    ffn_kind="gelu", norm="layernorm", use_bias=True,
    subquadratic=False,  # full attention => long_500k skipped (DESIGN.md)
)
