"""Sharded, atomic, async checkpointing with reshard-on-load.

Layout:  <dir>/step_00000042/  MANIFEST.json + one .npy per pytree leaf
         <dir>/LATEST          (text file naming the committed step dir)

Commit protocol: write into step_X.tmp, fsync files, atomic rename to
step_X, then update LATEST — a crash mid-save can never corrupt the
previously committed checkpoint (tested by simulating partial writes).

Reshard-on-load: leaves are stored as *global* arrays with their logical
shapes; on restore they are device_put against whatever mesh/sharding the
new job uses — so a checkpoint written on a 16x16 mesh restores onto
2x16x16 (elastic scaling) or onto a single CPU device (debugging).

Async: one background worker thread; ``save`` returns immediately after
snapshotting to host memory; ``wait()`` joins the in-flight write (the
trainer calls it before the next save and at exit).
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._err = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._inflight = threading.Semaphore(1)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot to host memory and enqueue an atomic write."""
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self._inflight.acquire()
        self._q.put((step, host))
        if blocking:
            self.wait()

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def _run(self):
        while True:
            step, host = self._q.get()
            try:
                self._write(step, host)
            except Exception as e:  # noqa: BLE001
                self._err = e
            finally:
                self._inflight.release()
                self._q.task_done()

    def _write(self, step: int, host: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for i, (key, arr) in enumerate(host.items()):
            fname = f"leaf_{i:05d}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest[key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.dir)
            if re.fullmatch(r"step_\d+", d)
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore

    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        name = open(latest).read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
            # LATEST points at a half-written dir: fall back to newest valid
            cands = sorted(
                d for d in os.listdir(self.dir)
                if re.fullmatch(r"step_\d+", d)
                and os.path.exists(os.path.join(self.dir, d, "MANIFEST.json"))
            )
            if not cands:
                return None
            name = cands[-1]
        return int(name.split("_")[1])

    def restore(self, step: int, tree_struct, shardings=None):
        """Load into the structure of ``tree_struct``; device_put against
        ``shardings`` (same tree) if given — reshard-on-load."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)["leaves"]
        keys = list(_flatten(tree_struct).keys())
        missing = [k for k in keys if k not in manifest]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}")
        leaves_struct, treedef = jax.tree_util.tree_flatten(tree_struct)
        flat_sh = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings else None
        )
        out = []
        for i, key in enumerate(keys):
            arr = np.load(os.path.join(path, manifest[key]["file"]))
            want = leaves_struct[i]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {want.shape}"
                )
            arr = arr.astype(want.dtype)
            if flat_sh is not None:
                out.append(jax.device_put(arr, flat_sh[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
