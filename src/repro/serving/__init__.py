"""Continuous-batching serving engine over the backend registry.

Modules:
  kvcache   — slot-paged KV pool (fixed page pool + refcounted allocator)
  scheduler — request queue, admission policies, stop conditions
  prefix    — radix-style prefix cache: shared prompt prefixes mapped to
              refcounted, content-addressed page slots
  pipeline  — discrete-event model of the §5.3 twelve-stage FWS pipeline
              (single- and multi-chip with inter-chip hop stages)
  engine    — user-facing Engine.add_request/step/run API (decoder LMs)
  load      — trace-driven load harness: Poisson / scripted arrivals
              replayed through the real Engine against SLOs
  vision    — single-stream image-throughput engine for encoder (ViT)
              workloads: measured stage traffic -> Table 7 FPS
"""

from repro.serving.engine import Engine, EngineConfig  # noqa: F401
from repro.serving.kvcache import (  # noqa: F401
    PagedKVCache,
    PoolExhausted,
    SlotAllocator,
)
from repro.serving.prefix import PrefixCache, PrefixHit  # noqa: F401
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
from repro.serving.vision import (  # noqa: F401
    VisionEngine,
    VisionReport,
    synthetic_stream_report,
)
