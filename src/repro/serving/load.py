"""Trace-driven load harness for the continuous-batching engine.

Production traffic is not a staggered for-loop: arrivals are bursty
(Poisson or recorded traces), prompt and output lengths are mixed, and
most prompts open with one of a handful of shared system prompts. This
module synthesizes exactly that workload and replays it through the real
:class:`~repro.serving.engine.Engine` on the host wall clock, so the
request-span tracer (``repro.obs``) measures TTFT / queue-wait /
per-token latency under genuine queueing pressure and
``evaluate_slo`` scores the run.

Pieces:
  * arrival processes — :func:`poisson_arrivals` (exponential
    inter-arrival gaps at a given requests/s rate) and scripted traces
    (:func:`load_trace` / :func:`save_trace`, JSON on disk) share the
    :class:`LoadRequest` record;
  * workload synthesis — :func:`synth_requests` draws prompt/output
    lengths from ranges and prefixes a fraction of prompts with shared
    system prompts (what gives the prefix cache something to hit);
  * replay — :func:`replay` submits each request when the host clock
    passes its arrival time (``speed`` compresses recorded time) and
    steps the engine in between;
  * reporting — :func:`load_report` folds the engine's telemetry into
    one dict (p50/p99 TTFT, per-token latency, prefix-cache hit rate,
    eviction counts, SLO verdict) ready for ``BENCH_serving.json``.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.obs import SLOTargets, evaluate_slo


@dataclasses.dataclass(frozen=True)
class LoadRequest:
    t: float  # arrival time, seconds from trace start
    prompt: tuple[int, ...]
    max_new: int


# ------------------------------------------------------------- arrivals

def poisson_arrivals(rate_rps: float, n: int, rng) -> np.ndarray:
    """``n`` arrival times with exponential inter-arrival gaps at
    ``rate_rps`` requests/second (a Poisson process)."""
    if rate_rps <= 0:
        raise ValueError("rate must be > 0")
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def burst_arrivals(n: int, burst: int, gap_s: float) -> np.ndarray:
    """Deterministic scripted process: bursts of ``burst`` simultaneous
    arrivals every ``gap_s`` seconds — the adversarial case for admission
    (queue spikes) and the friendly case for the prefix cache (a burst
    shares its system prompt)."""
    return np.asarray([(i // burst) * gap_s for i in range(n)])


def parse_arrivals(spec: str):
    """CLI arrival spec: ``poisson:RATE`` | ``trace:FILE`` |
    ``burst:N:GAP_S``. Returns ``(kind, value)``."""
    kind, _, val = spec.partition(":")
    if kind == "poisson":
        return "poisson", float(val)
    if kind == "trace":
        if not val:
            raise ValueError("trace arrivals need a file: trace:FILE")
        return "trace", val
    if kind == "burst":
        n, _, gap = val.partition(":")
        return "burst", (int(n), float(gap or "0.05"))
    raise ValueError(f"unknown arrivals spec {spec!r} "
                     "(poisson:RATE | trace:FILE | burst:N:GAP_S)")


# ------------------------------------------------------------- workload

@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Mixed prompt/output-length + shared-system-prompt distribution."""

    vocab_size: int
    prompt_len: tuple[int, int] = (2, 16)  # inclusive user-suffix range
    out_len: tuple[int, int] = (2, 8)  # inclusive max_new range
    n_system: int = 2  # distinct shared system prompts
    system_len: int = 8  # tokens per system prompt
    p_shared: float = 0.75  # fraction of prompts opening with one
    max_prompt: int | None = None  # cap (engine page/prefill budget)


def synth_requests(spec: WorkloadSpec, n: int, rng) -> list[tuple[list, int]]:
    """Draw ``n`` (prompt, max_new) pairs from the workload spec."""
    systems = [
        rng.integers(0, spec.vocab_size, size=spec.system_len).tolist()
        for _ in range(spec.n_system)
    ]
    out = []
    for _ in range(n):
        body = rng.integers(
            0, spec.vocab_size,
            size=int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1)),
        ).tolist()
        prompt = body
        if systems and rng.random() < spec.p_shared:
            prompt = systems[int(rng.integers(len(systems)))] + body
        if spec.max_prompt is not None:
            prompt = prompt[:spec.max_prompt]
        out.append(
            (prompt, int(rng.integers(spec.out_len[0], spec.out_len[1] + 1)))
        )
    return out


def make_trace(arrival_times, requests) -> list[LoadRequest]:
    return [
        LoadRequest(t=float(t), prompt=tuple(p), max_new=m)
        for t, (p, m) in zip(arrival_times, requests)
    ]


def save_trace(path: str, trace: list[LoadRequest]) -> None:
    with open(path, "w") as f:
        json.dump({"requests": [
            {"t": r.t, "prompt": list(r.prompt), "max_new": r.max_new}
            for r in trace
        ]}, f)


def load_trace(path: str) -> list[LoadRequest]:
    with open(path) as f:
        doc = json.load(f)
    return [
        LoadRequest(t=float(r["t"]), prompt=tuple(int(t) for t in r["prompt"]),
                    max_new=int(r["max_new"]))
        for r in doc["requests"]
    ]


# --------------------------------------------------------------- replay

def replay(engine, trace: list[LoadRequest], speed: float = 1.0,
           max_steps: int = 1_000_000) -> dict:
    """Wall-clock replay: submit each request when the host clock passes
    ``t / speed``, stepping the engine in between (idle gaps sleep in
    sub-millisecond slices so arrival timing stays honest). Returns
    ``{rid: out tokens}`` plus replay wall time."""
    trace = sorted(trace, key=lambda r: r.t)
    rids: list[int] = []
    t0 = time.perf_counter()
    i, steps = 0, 0
    while i < len(trace) or engine.sched.has_work:
        now = (time.perf_counter() - t0) * speed
        while i < len(trace) and trace[i].t <= now:
            rids.append(engine.add_request(list(trace[i].prompt),
                                           max_new=trace[i].max_new))
            i += 1
        if engine.sched.has_work:
            engine.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"replay did not drain in {max_steps} "
                                   "steps")
        elif i < len(trace):
            wait = trace[i].t / speed - (time.perf_counter() - t0)
            time.sleep(min(max(wait, 0.0), 5e-4))
    return {
        "out": {rid: list(engine.requests[rid].out) for rid in rids},
        "wall_s": time.perf_counter() - t0,
        "steps": steps,
    }


# ------------------------------------------------------------ reporting

def load_report(engine, targets: SLOTargets | None = None,
                wall_s: float | None = None) -> dict:
    """Fold one replayed run into the BENCH_serving.json "load" schema:
    request-latency percentiles, step mix, prefix-cache stats, eviction
    counts, and (when targets are given) the SLO verdict."""
    obs = engine.obs
    summ = obs.request_summary()
    kinds = [e.kind for e in obs.steps]
    reasons = summ.get("finish_reasons", {})
    # obs-derived counts so a jit-warmup run followed by obs.reset()
    # doesn't leak into the report
    n_tok = summ.get("n_tokens", 0)
    rep = {
        "n_requests": summ.get("n_requests", 0),
        "tokens_generated": n_tok,
        "steps": {"prefill": kinds.count("prefill"),
                  "decode": kinds.count("decode")},
        "ttft_s": summ.get("ttft_s"),
        "token_latency_s": summ.get("token_latency_s"),
        "queue_wait_s": summ.get("queue_wait_s"),
        "e2e_s": summ.get("e2e_s"),
        "finish_reasons": reasons,
        "page_evictions": reasons.get("page_exhausted", 0),
        "slot_utilization": engine.slot_utilization,
        "prefix": engine.prefix_stats(),
    }
    if wall_s is not None:
        rep["wall_s"] = wall_s
        rep["tokens_per_s_wall"] = n_tok / wall_s if wall_s > 0 else 0.0
    if targets is not None:
        rep["slo"] = evaluate_slo(obs.finished, targets)
    return rep
