"""Continuous-batching inference engine over the backend registry.

``Engine`` glues the pieces together: a :class:`PagedKVCache` pool, a
:class:`Scheduler`, and two *fixed-shape* jitted steps —

  prefill  [1, prefill_len]   one padded prompt into its allocated slot
  decode   [lanes, 1]         one token per lane at per-lane positions

so XLA compiles each shape exactly once regardless of how requests come
and go. Prompts are right-padded to ``prefill_len`` with ``KV_PAD``
positions (masked out of attention by ``layers.attention._mask``); decode
lanes without an active request park on their scratch row and their
outputs are discarded on the host. Works under any linear-execution
backend (float / mxfp4 / cim) because the steps just call
``lm.forward``/``lm.decode_step`` with whatever converted params + RunCtx
the caller built (see ``launch/serve.py::build_backend``).

Telemetry: the engine emits typed lifecycle events through a
``repro.obs.Obs`` handle — enqueue -> admitted -> prefill/first-token ->
per-decode-step -> finish/evict — yielding per-request TTFT, queue-wait,
per-token latency, occupancy and eviction metrics. The old ad-hoc
``(kind, rids, n_tokens)`` tuple trace survives as the derived
``Engine.trace`` view, which ``serving/pipeline.py`` maps onto the
twelve-stage FWS pipeline for simulated latency/throughput reporting.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers import attention as attn_mod
from repro.models import lm
from repro.obs import Obs
from repro.serving import pipeline as pipe_mod
from repro.serving.kvcache import PagedKVCache, gather_rows, scatter_rows
from repro.serving.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    lanes: int = 4  # decode batch width
    num_slots: int = 6  # KV pages in the pool (>= lanes to be useful)
    page_len: int = 48  # positions per page (prompt + generated)
    prefill_len: int = 16  # fixed prefill shape; prompts pad up to this
    policy: str = "prefill"  # admission policy (see scheduler.py)
    # "fused" switches the pool to the head-interleaved paged layout and
    # decodes in place through the ragged paged flash-decode path —
    # RunCtx.paged_rows maps lanes to pool rows inside the jitted step,
    # so a decode step does O(lanes) KV writes instead of gathering and
    # scattering full pages
    kv_layout: str = "legacy"  # legacy | fused


class Engine:
    def __init__(self, params, cfg, ctx, ecfg: EngineConfig = EngineConfig(),
                 obs: Obs | None = None):
        if ecfg.prefill_len > ecfg.page_len:
            raise ValueError("prefill_len must fit in a page")
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.ecfg = ecfg
        self.obs = obs if obs is not None else Obs()
        # hybrid / fully-digital MXFP4 SDPA: the pool keeps K/V codes
        # resident so decode quantization is O(1) in cache length
        self.kv = PagedKVCache(cfg, ecfg.num_slots, ecfg.lanes, ecfg.page_len,
                               mx_digital=ctx.hybrid_digital_sdpa,
                               layout=ecfg.kv_layout)
        self.sched = Scheduler(ecfg.lanes, ecfg.policy, obs=self.obs)
        self.requests: dict[int, Request] = {}
        self._next_rid = 0
        self._step_idx = 0
        self._prefill, self._decode = self._build_steps()

    # ------------------------------------------------------- jitted steps

    def _build_steps(self):
        cfg, ctx, ecfg = self.cfg, self.ctx, self.ecfg
        specs = self.kv.specs

        def prefill(params, pool, ids, positions, row, last):
            caches = lm.init_cache(cfg, 1, ecfg.page_len,
                                   mx_digital=self.kv.mx_digital,
                                   fused=self.kv.fused)
            hidden, caches = lm.forward(
                params, cfg, ctx, {"ids": ids, "positions": positions},
                caches=caches, return_hidden=True,
            )
            # head over the real last position only (padded tail discarded).
            # Pad rows of the written page are already zero: attn_apply
            # zeroes K/V at KV_PAD positions and init_cache zero-fills
            # beyond the prefill width.
            logits = lm._head(ctx, cfg, params, hidden[:, last][:, None])
            pool = scatter_rows(pool, specs, row, caches)
            return jnp.argmax(logits[0, 0].astype(jnp.float32)), pool

        def decode(params, pool, rows, ids, pos):
            caches = gather_rows(pool, specs, rows)
            logits, caches = lm.decode_step(params, cfg, ctx, ids, pos, caches)
            pool = scatter_rows(pool, specs, rows, caches)
            return jnp.argmax(logits.astype(jnp.float32), -1), pool

        def decode_fused(params, pool, rows, ids, pos):
            # in-place paged decode: lanes address their pool rows through
            # RunCtx.paged_rows (threaded inside the trace — never closed
            # over), so no page gather/scatter brackets the step
            dctx = dataclasses.replace(ctx, paged_rows=rows)
            logits, pool = lm.decode_step(params, cfg, dctx, ids, pos, pool)
            return jnp.argmax(logits.astype(jnp.float32), -1), pool

        if self.kv.fused:
            decode = decode_fused

        return (
            jax.jit(prefill, donate_argnums=(1,)),
            jax.jit(decode, donate_argnums=(1,)),
        )

    # --------------------------------------------------------- public API

    def add_request(self, prompt, max_new: int, stop_token: int | None = None
                    ) -> int:
        prompt = [int(t) for t in prompt]
        if not prompt or len(prompt) > self.ecfg.prefill_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, "
                f"{self.ecfg.prefill_len}]"
            )
        if max_new < 1:
            raise ValueError("max_new must be >= 1 (prefill emits a token)")
        if len(prompt) + max_new > self.ecfg.page_len:
            raise ValueError("prompt + max_new overflows the KV page")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      stop_token=stop_token, arrival=self._step_idx)
        self.requests[rid] = req
        self.sched.add(req)
        self.obs.request_enqueued(rid, n_prompt=len(prompt))
        return rid

    def step(self) -> list:
        """One scheduled unit of work (a prefill or a decode step).
        Returns the requests that finished during this step."""
        action = self.sched.plan(self.kv.num_free)
        if action == "idle":
            return []
        self._step_idx += 1
        done = (self._run_prefill() if action == "prefill"
                else self._run_decode())
        self.obs.lanes_state(len(self.sched.waiting), self.sched.num_active,
                             self.kv.num_free)
        return done

    def run(self, max_steps: int = 100_000) -> dict:
        """Drive until every queued request completes. Returns
        {rid: generated token list}."""
        for _ in range(max_steps):
            if not self.sched.has_work:
                break
            self.step()
        if self.sched.has_work:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return {rid: list(r.out) for rid, r in self.requests.items()}

    @property
    def trace(self) -> list:
        """Derived view: the classic (kind, rids, n_tokens) tuple list
        the pipeline model consumes, rebuilt from the typed step events
        (``self.obs.steps``)."""
        return self.obs.legacy_trace()

    def trace_report(self) -> pipe_mod.TraceReport:
        """Map the recorded schedule onto the FWS pipeline model."""
        return pipe_mod.simulate_trace(
            self.obs.steps, self.cfg.d_model, self.ecfg.lanes
        )

    @property
    def slot_utilization(self) -> float:
        """Mean fraction of decode lanes doing live work (vs parked)."""
        decodes = [len(e.rids) for e in self.obs.steps
                   if e.kind == "decode"]
        if not decodes:
            return 1.0
        return sum(decodes) / (self.ecfg.lanes * len(decodes))

    # ----------------------------------------------------------- internals

    def _run_prefill(self) -> list:
        t0 = self.obs.clock()
        slot = self.kv.allocator.alloc()
        req = self.sched.admit(slot, self._step_idx)
        self.obs.request_admitted(req.rid)
        n = len(req.prompt)
        p = self.ecfg.prefill_len
        ids = np.zeros((1, p), np.int32)
        ids[0, :n] = req.prompt
        positions = np.full((1, p), attn_mod.KV_PAD, np.int32)
        positions[0, :n] = np.arange(n)
        tok, self.kv.pool = self._prefill(
            self.params, self.kv.pool, jnp.asarray(ids),
            jnp.asarray(positions), jnp.asarray([slot], jnp.int32),
            jnp.int32(n - 1),
        )
        req.out.append(int(tok))  # device sync: the step is complete here
        t1 = self.obs.clock()
        self.obs.step_recorded("prefill", (req.rid,), n, t0, t1)
        self.obs.token_emitted(req.rid, t1)  # prefill emits the first token
        return self._retire([req])

    def _run_decode(self) -> list:
        t0 = self.obs.clock()
        ecfg = self.ecfg
        rows = np.asarray(
            [self.kv.scratch_row(i) for i in range(ecfg.lanes)], np.int32
        )
        ids = np.zeros((ecfg.lanes, 1), np.int32)
        pos = np.zeros((ecfg.lanes,), np.int32)
        active = sorted(self.sched.running.items())
        for lane, req in active:
            rows[lane] = req.slot
            ids[lane, 0] = req.out[-1]
            pos[lane] = req.pos
        next_ids, self.kv.pool = self._decode(
            self.params, self.kv.pool, jnp.asarray(rows), jnp.asarray(ids),
            jnp.asarray(pos),
        )
        next_ids = np.asarray(next_ids)  # device sync
        t1 = self.obs.clock()
        for lane, req in active:
            req.out.append(int(next_ids[lane]))
            req.pos += 1
            self.obs.token_emitted(req.rid, t1)
        self.obs.step_recorded(
            "decode", tuple(r.rid for _, r in active), len(active), t0, t1,
            lanes=ecfg.lanes,
        )
        return self._retire([r for _, r in active])

    def _retire(self, reqs) -> list:
        done = []
        for req in reqs:
            reason = Scheduler.stop_reason(req, self.ecfg.page_len)
            if reason is not None:
                self.sched.finish(req, self._step_idx)
                self.kv.allocator.free(req.slot)
                self.obs.request_finished(req.rid, reason)
                done.append(req)
        return done
