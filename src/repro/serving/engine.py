"""Continuous-batching inference engine over the backend registry.

``Engine`` glues the pieces together: a :class:`PagedKVCache` pool, a
:class:`Scheduler`, an optional :class:`PrefixCache`, and a set of
*fixed-shape* jitted steps —

  prefill  [1, prefill_len]   one padded prompt into its allocated slot
  chunk    [1, chunk_len]     one window of a longer prompt into its slot
                              (looped; lifts the prompt cap to page_len
                              without recompiles)
  decode   [lanes, 1]         one token per lane at per-lane positions

so XLA compiles each shape exactly once regardless of how requests come
and go. Prompts are right-padded with ``KV_PAD`` positions (masked out of
attention by ``layers.attention._mask``); decode lanes without an active
request park on their scratch row and their outputs are discarded on the
host. Works under any linear-execution backend (float / mxfp4 / cim)
because the steps just call ``lm.forward``/``lm.decode_step`` with
whatever converted params + RunCtx the caller built (see
``launch/serve.py::build_backend``).

Chunked prefill (``chunk_len``) feeds long prompts through the fixed
``[1, chunk_len]`` step one window at a time; under the ``chunked``
scheduler policy those windows interleave with decode steps so a long
prompt no longer stalls live lanes. Admission always starts by cloning /
resetting the request's page (``kvcache.clone_prefix``): with the prefix
cache on (``prefix_cache=True``) the longest chunk-aligned cached prefix
is copied from the donor page — copy-on-write at the divergence point —
and only the suffix chunks run; on a miss the clone degenerates to a
page zeroing (reused slots carry stale rows that would otherwise corrupt
shared-exponent blocks of the quantized-resident mirrors).

Telemetry: the engine emits typed lifecycle events through a
``repro.obs.Obs`` handle — enqueue -> admitted -> prefill/first-token ->
per-decode-step -> finish/evict — yielding per-request TTFT, queue-wait,
per-token latency, occupancy and eviction metrics. Prefill step events
bill the *executed* width (``prefill_len`` or ``chunk_len``), not the
prompt length: the jitted step pushes the full padded window through the
FWS pipeline whether or not the tail is padding, and the pipeline model
should see that. The old ad-hoc ``(kind, rids, n_tokens)`` tuple trace
survives as the derived ``Engine.trace`` view, which
``serving/pipeline.py`` maps onto the twelve-stage FWS pipeline for
simulated latency/throughput reporting.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers import attention as attn_mod
from repro.models import lm
from repro.obs import Obs
from repro.serving import pipeline as pipe_mod
from repro.serving.kvcache import (
    PagedKVCache,
    PoolExhausted,
    clone_prefix,
    gather_rows,
    scatter_rows,
)
from repro.serving.prefix import PrefixCache
from repro.serving.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    lanes: int = 4  # decode batch width
    num_slots: int = 6  # KV pages in the pool (>= lanes to be useful)
    page_len: int = 48  # positions per page (prompt + generated)
    prefill_len: int = 16  # fixed prefill shape; prompts pad up to this
    policy: str = "prefill"  # admission policy (see scheduler.py)
    # "fused" switches the pool to the head-interleaved paged layout and
    # decodes in place through the ragged paged flash-decode path —
    # RunCtx.paged_rows maps lanes to pool rows inside the jitted step,
    # so a decode step does O(lanes) KV writes instead of gathering and
    # scattering full pages
    kv_layout: str = "legacy"  # legacy | fused
    # chunked prefill: prompts run through a fixed [1, chunk_len] step in
    # absolute-position windows, lifting the prompt cap from prefill_len
    # to page_len. None keeps the single-shot padded prefill (and its
    # exact numerics — chunked attention quantizes over page-width keys,
    # so cim outputs differ statistically, not bitwise, from single-shot)
    chunk_len: int | None = None
    # radix prefix cache over the page pool (requires chunk_len: hits are
    # chunk-aligned so cached pages drop into the same chunk grid)
    prefix_cache: bool = False


class Engine:
    def __init__(self, params, cfg, ctx, ecfg: EngineConfig = EngineConfig(),
                 obs: Obs | None = None):
        if ecfg.prefill_len > ecfg.page_len:
            raise ValueError("prefill_len must fit in a page")
        if ecfg.chunk_len is not None and not (
                2 <= ecfg.chunk_len <= ecfg.page_len):
            # >= 2: the fixed-shape chunk step must take attention's
            # multi-token prefill branch, not the decode branch
            raise ValueError("chunk_len must be in [2, page_len]")
        if ecfg.prefix_cache and ecfg.chunk_len is None:
            raise ValueError("prefix_cache requires chunk_len (hits are "
                             "chunk-aligned)")
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.ecfg = ecfg
        self.obs = obs if obs is not None else Obs()
        # hybrid / fully-digital MXFP4 SDPA: the pool keeps K/V codes
        # resident so decode quantization is O(1) in cache length
        self.kv = PagedKVCache(cfg, ecfg.num_slots, ecfg.lanes, ecfg.page_len,
                               mx_digital=ctx.hybrid_digital_sdpa,
                               layout=ecfg.kv_layout)
        self.sched = Scheduler(ecfg.lanes, ecfg.policy, obs=self.obs)
        self.prefix: PrefixCache | None = None
        if ecfg.prefix_cache:
            self.prefix = PrefixCache(ecfg.chunk_len, self.kv.allocator,
                                      obs=self.obs)
        self.requests: dict[int, Request] = {}
        self._next_rid = 0
        self._step_idx = 0
        self._prefill, self._decode, self._chunk, self._clone = \
            self._build_steps()

    # ------------------------------------------------------- jitted steps

    def _build_steps(self):
        cfg, ctx, ecfg = self.cfg, self.ctx, self.ecfg
        specs = self.kv.specs

        def prefill(params, pool, ids, positions, row, last):
            caches = lm.init_cache(cfg, 1, ecfg.page_len,
                                   mx_digital=self.kv.mx_digital,
                                   fused=self.kv.fused)
            hidden, caches = lm.forward(
                params, cfg, ctx, {"ids": ids, "positions": positions},
                caches=caches, return_hidden=True,
            )
            # head over the real last position only (padded tail discarded).
            # Pad rows of the written page are already zero: attn_apply
            # zeroes K/V at KV_PAD positions and init_cache zero-fills
            # beyond the prefill width.
            logits = lm._head(ctx, cfg, params, hidden[:, last][:, None])
            pool = scatter_rows(pool, specs, row, caches)
            return jnp.argmax(logits[0, 0].astype(jnp.float32)), pool

        def decode(params, pool, rows, ids, pos):
            caches = gather_rows(pool, specs, rows)
            logits, caches = lm.decode_step(params, cfg, ctx, ids, pos, caches)
            pool = scatter_rows(pool, specs, rows, caches)
            return jnp.argmax(logits.astype(jnp.float32), -1), pool

        def decode_fused(params, pool, rows, ids, pos):
            # in-place paged decode: lanes address their pool rows through
            # RunCtx.paged_rows (threaded inside the trace — never closed
            # over), so no page gather/scatter brackets the step
            dctx = dataclasses.replace(ctx, paged_rows=rows)
            logits, pool = lm.decode_step(params, cfg, dctx, ids, pos, pool)
            return jnp.argmax(logits.astype(jnp.float32), -1), pool

        def chunk(params, pool, row, ids, positions, offset, last):
            # one [1, chunk_len] window of a longer prompt, written into
            # the request's page at absolute positions (attn_apply's
            # chunked-prefill branch, selected by pos=offset). The page
            # was cloned/zeroed at admission, so rows beyond the written
            # prefix are deterministic zeros.
            caches = gather_rows(pool, specs, row)
            hidden, caches = lm.forward(
                params, cfg, ctx, {"ids": ids, "positions": positions},
                caches=caches, pos=offset, return_hidden=True,
            )
            logits = lm._head(ctx, cfg, params, hidden[:, last][:, None])
            pool = scatter_rows(pool, specs, row, caches)
            return jnp.argmax(logits[0, 0].astype(jnp.float32)), pool

        def clone(pool, src, dst, n):
            return clone_prefix(pool, specs, src, dst, n)

        chunked = ecfg.chunk_len is not None
        return (
            jax.jit(prefill, donate_argnums=(1,)),
            jax.jit(decode_fused if self.kv.fused else decode,
                    donate_argnums=(1,)),
            jax.jit(chunk, donate_argnums=(1,)) if chunked else None,
            jax.jit(clone, donate_argnums=(0,)) if chunked else None,
        )

    # --------------------------------------------------------- public API

    def add_request(self, prompt, max_new: int, stop_token: int | None = None
                    ) -> int:
        prompt = [int(t) for t in prompt]
        limit = (self.ecfg.page_len if self.ecfg.chunk_len is not None
                 else self.ecfg.prefill_len)
        if not prompt or len(prompt) > limit:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, {limit}]"
            )
        if max_new < 1:
            raise ValueError("max_new must be >= 1 (prefill emits a token)")
        # NOTE: len(prompt) + max_new may exceed page_len. The request
        # then finishes with reason "page_exhausted" once its page fills
        # — the eviction path. (An older guard rejected these up front,
        # which made the scheduler's page_exhausted arm dead code.)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      stop_token=stop_token, arrival=self._step_idx)
        self.requests[rid] = req
        self.sched.add(req)
        self.obs.request_enqueued(rid, n_prompt=len(prompt))
        return rid

    def step(self) -> list:
        """One scheduled unit of work (a prefill / prefill chunk or a
        decode step). Returns the requests that finished during it."""
        avail = self.kv.num_free + (
            self.prefix.n_evictable if self.prefix is not None else 0
        )
        action = self.sched.plan(avail)
        if action == "idle":
            return []
        self._step_idx += 1
        if action == "prefill":
            done = (self._run_prefill_chunk()
                    if self.ecfg.chunk_len is not None
                    else self._run_prefill())
        else:
            done = self._run_decode()
        self.obs.lanes_state(len(self.sched.waiting), self.sched.num_active,
                             self.kv.num_free)
        return done

    def run(self, max_steps: int = 100_000) -> dict:
        """Drive until every queued request completes. Returns
        {rid: generated token list}."""
        for _ in range(max_steps):
            if not self.sched.has_work:
                break
            self.step()
        if self.sched.has_work:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return {rid: list(r.out) for rid, r in self.requests.items()}

    @property
    def trace(self) -> list:
        """Derived view: the classic (kind, rids, n_tokens) tuple list
        the pipeline model consumes, rebuilt from the typed step events
        (``self.obs.steps``)."""
        return self.obs.legacy_trace()

    def trace_report(self) -> pipe_mod.TraceReport:
        """Map the recorded schedule onto the FWS pipeline model."""
        return pipe_mod.simulate_trace(
            self.obs.steps, self.cfg.d_model, self.ecfg.lanes
        )

    @property
    def slot_utilization(self) -> float:
        """Mean fraction of decode lanes doing live work (vs parked)."""
        decodes = [len(e.rids) for e in self.obs.steps
                   if e.kind == "decode"]
        if not decodes:
            return 1.0
        return sum(decodes) / (self.ecfg.lanes * len(decodes))

    def prefix_stats(self) -> dict:
        return self.prefix.stats() if self.prefix is not None else {}

    # ----------------------------------------------------------- internals

    def _alloc_slot(self) -> int:
        """A page slot for an admission, evicting LRU prefix-cache pages
        if the free list is dry. Raises :class:`PoolExhausted` when the
        scheduler mis-planned (every slot referenced by a live request)
        — never feeds a non-slot into the jitted step."""
        while True:
            slot = self.kv.allocator.try_alloc()
            if slot is not None:
                return slot
            if self.prefix is None or not self.prefix.evict_lru():
                raise PoolExhausted(
                    "no free KV page slots and no evictable cached pages "
                    f"(num_slots={self.ecfg.num_slots})"
                )

    def _admit_chunked(self) -> Request:
        """Admission for the chunked path: prefix-cache lookup, slot
        allocation (with LRU eviction), and the page clone/reset."""
        nxt = self.sched.waiting[0]
        hit = (self.prefix.match(nxt.prompt, self.kv)
               if self.prefix is not None else None)
        if hit is not None:
            # pin the donor page: allocation below may need an LRU
            # eviction, which must not pick the page we are cloning from
            self.kv.allocator.retain(hit.slot)
        try:
            slot = self._alloc_slot()
        except PoolExhausted:
            if hit is None:
                raise
            # the donor was the only evictable page — give it up for the
            # admission itself; the hit degrades to a miss
            self.kv.allocator.release(hit.slot)
            hit = None
            slot = self._alloc_slot()
        req = self.sched.begin_prefill(slot, self._step_idx)
        self.obs.request_admitted(req.rid)
        src = hit.slot if hit is not None else slot
        n = hit.n_tokens if hit is not None else 0
        # always clone: n=0 zeroes the (possibly reused, stale) page so
        # the quantized-resident mirror invariant survives; n>0 is the
        # prefix copy-on-write
        self.kv.pool = self._clone(
            self.kv.pool, jnp.int32(src), jnp.int32(slot), jnp.int32(n)
        )
        if hit is not None:
            req.prefilled = req.prefix_hit = hit.n_tokens
            self.kv.allocator.release(hit.slot)
        return req

    def _run_prefill(self) -> list:
        t0 = self.obs.clock()
        slot = self._alloc_slot()
        req = self.sched.admit(slot, self._step_idx)
        self.obs.request_admitted(req.rid)
        n = len(req.prompt)
        p = self.ecfg.prefill_len
        ids = np.zeros((1, p), np.int32)
        ids[0, :n] = req.prompt
        positions = np.full((1, p), attn_mod.KV_PAD, np.int32)
        positions[0, :n] = np.arange(n)
        tok, self.kv.pool = self._prefill(
            self.params, self.kv.pool, jnp.asarray(ids),
            jnp.asarray(positions), jnp.asarray([slot], jnp.int32),
            jnp.int32(n - 1),
        )
        req.out.append(int(tok))  # device sync: the step is complete here
        t1 = self.obs.clock()
        # bill the executed width: the fixed-shape step pushes all
        # prefill_len positions through the pipeline, padding included.
        # The request span keeps the real prompt length (request_enqueued)
        # for TTFT/queue accounting.
        self.obs.step_recorded("prefill", (req.rid,), p, t0, t1)
        self.obs.token_emitted(req.rid, t1)  # prefill emits the first token
        return self._retire([req])

    def _run_prefill_chunk(self) -> list:
        t0 = self.obs.clock()
        L = self.ecfg.chunk_len
        req = self.sched.prefilling
        if req is None:
            req = self._admit_chunked()
        offs = req.prefilled
        take = min(L, len(req.prompt) - offs)
        ids = np.zeros((1, L), np.int32)
        ids[0, :take] = req.prompt[offs:offs + take]
        positions = np.full((1, L), attn_mod.KV_PAD, np.int32)
        positions[0, :take] = np.arange(offs, offs + take)
        tok, self.kv.pool = self._chunk(
            self.params, self.kv.pool, jnp.asarray([req.slot], jnp.int32),
            jnp.asarray(ids), jnp.asarray(positions), jnp.int32(offs),
            jnp.int32(take - 1),
        )
        tok = int(tok)  # device sync: the step is complete here
        t1 = self.obs.clock()
        self.obs.step_recorded("prefill", (req.rid,), L, t0, t1)
        req.prefilled = offs + take
        if req.prefilled < len(req.prompt):
            return []
        # prompt fully resident: the last chunk's logits are the first
        # generated token, and the finished prefix becomes donatable
        self.sched.finish_prefill(req)
        req.out.append(tok)
        self.obs.token_emitted(req.rid, t1)
        if self.prefix is not None:
            self.prefix.insert(req.prompt, req.slot, self.kv)
        return self._retire([req])

    def _run_decode(self) -> list:
        t0 = self.obs.clock()
        ecfg = self.ecfg
        rows = np.asarray(
            [self.kv.scratch_row(i) for i in range(ecfg.lanes)], np.int32
        )
        ids = np.zeros((ecfg.lanes, 1), np.int32)
        pos = np.zeros((ecfg.lanes,), np.int32)
        active = sorted(self.sched.running.items())
        for lane, req in active:
            rows[lane] = req.slot
            ids[lane, 0] = req.out[-1]
            pos[lane] = req.pos
        next_ids, self.kv.pool = self._decode(
            self.params, self.kv.pool, jnp.asarray(rows), jnp.asarray(ids),
            jnp.asarray(pos),
        )
        next_ids = np.asarray(next_ids)  # device sync
        t1 = self.obs.clock()
        for lane, req in active:
            req.out.append(int(next_ids[lane]))
            req.pos += 1
            self.obs.token_emitted(req.rid, t1)
        self.obs.step_recorded(
            "decode", tuple(r.rid for _, r in active), len(active), t0, t1,
            lanes=ecfg.lanes,
        )
        return self._retire([r for _, r in active])

    def _retire(self, reqs) -> list:
        done = []
        for req in reqs:
            reason = Scheduler.stop_reason(req, self.ecfg.page_len)
            if reason is not None:
                self.sched.finish(req, self._step_idx)
                # drop the engine's reference; the prefix cache may still
                # hold its own (insert at prefill-complete), keeping the
                # page warm for future shared-prefix admissions
                self.kv.allocator.free(req.slot)
                self.obs.request_finished(req.rid, reason)
                done.append(req)
        return done
