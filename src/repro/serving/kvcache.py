"""Slot-paged KV cache for continuous-batching decode.

The pool is the ordinary per-segment cache tree from ``lm.init_cache``,
allocated once with ``num_slots + lanes`` rows along the batch axis and
``page_len`` positions along the cache-sequence axis. The first
``num_slots`` rows are *slots* — one resident page per in-flight request,
handed out by the pure-Python :class:`SlotAllocator`. The trailing
``lanes`` rows are per-lane *scratch* rows: an idle decode lane is parked
on its own scratch row, so the lane->row index vector is always injective
and the jitted gather (``jnp.take``) / scatter (``.at[rows].set``) pair
stays deterministic with no masking inside the step.

    pool row:   0 .. num_slots-1          request pages (allocator-owned)
                num_slots .. +lanes-1     scratch rows (lane i parks on
                                          row num_slots + i)

Cache leaves are not all batch-leading — scanned segments stack a layer
axis in front (``("layers", "batch", ...)``), so the batch-axis index per
leaf comes from ``lm.cache_specs``. ``gather_rows``/``scatter_rows`` are
pure functions over (pool, rows) and are meant to be called *inside* the
jitted prefill/decode steps.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models import lm


class PoolExhausted(RuntimeError):
    """Raised when a page slot is requested from an empty pool."""


@dataclasses.dataclass
class SlotAllocator:
    """Refcounted LIFO free-list over ``num_slots`` page slots.

    Host-side only. A slot is handed out with refcount 1; the prefix
    cache (`serving.prefix`) takes additional references on pages it
    shares between requests via :meth:`retain`. A slot returns to the
    free list only when its refcount drops to zero, so a cached page can
    outlive the request that prefilled it and a live request's page can
    never be recycled by a cache eviction.
    """

    num_slots: int

    def __post_init__(self):
        self._free = list(range(self.num_slots - 1, -1, -1))
        self._refs: dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> set[int]:
        return set(self._refs)

    def alloc(self) -> int:
        """Pop a free slot (refcount 1). Raises :class:`PoolExhausted`
        when the pool is empty — the old ``None`` return flowed straight
        into the jitted step as a row index (engine bug)."""
        if not self._free:
            raise PoolExhausted(
                f"no free KV page slots (num_slots={self.num_slots}, "
                f"all referenced)"
            )
        slot = self._free.pop()
        self._refs[slot] = 1
        return slot

    def try_alloc(self) -> int | None:
        """Like :meth:`alloc` but returns ``None`` on an empty pool."""
        return self.alloc() if self._free else None

    def retain(self, slot: int) -> None:
        if slot not in self._refs:
            raise ValueError(f"slot {slot} is not allocated")
        self._refs[slot] += 1

    def release(self, slot: int) -> None:
        """Drop one reference; the slot is freed at refcount zero."""
        if slot not in self._refs:
            raise ValueError(f"slot {slot} is not allocated")
        self._refs[slot] -= 1
        if self._refs[slot] == 0:
            del self._refs[slot]
            self._free.append(slot)

    # the engine owns exactly one reference per in-flight request, so its
    # retire path reads naturally as free()
    free = release

    def refcount(self, slot: int) -> int:
        return self._refs.get(slot, 0)


def _batch_axis(spec: tuple) -> int:
    return spec.index("batch")


def gather_rows(pool, specs, rows):
    """Gather cache rows ``rows`` (int32 [R]) out of the pool along each
    leaf's batch axis -> a regular R-row cache tree for lm.decode_step."""
    out = []
    for seg_cache, seg_spec in zip(pool, specs):
        out.append({
            k: jnp.take(v, rows, axis=_batch_axis(seg_spec[k]))
            for k, v in seg_cache.items()
        })
    return out


def scatter_rows(pool, specs, rows, values):
    """Write an R-row cache tree back into pool rows ``rows``. Rows must
    be unique (slots are, and idle lanes park on per-lane scratch rows).

    Leaf dtypes must round-trip: a value leaf whose dtype does not
    promote losslessly to the pool leaf's dtype (e.g. f32 pages written
    into a bf16 pool) raises instead of silently truncating mantissas on
    the way back in."""
    out = []
    for seg_pool, seg_spec, seg_val in zip(pool, specs, values):
        seg = {}
        for k, v in seg_pool.items():
            val = seg_val[k]
            if val.dtype != v.dtype and (
                jnp.promote_types(val.dtype, v.dtype) != v.dtype
            ):
                raise TypeError(
                    f"scatter_rows: lossy write of {k}: {val.dtype} "
                    f"values into a {v.dtype} pool leaf"
                )
            ax = _batch_axis(seg_spec[k])
            idx = (slice(None),) * ax + (rows,)
            seg[k] = v.at[idx].set(val.astype(v.dtype))
        out.append(seg)
    return out


def clone_prefix(pool, specs, src_row, dst_row, n):
    """Copy the first ``n`` cache-sequence rows of page ``src_row`` into
    page ``dst_row`` and zero everything beyond them.

    Called (jitted) at chunked admission time. With ``n == 0`` this is a
    pure page reset — required because reused slots carry stale rows, and
    a stale raw row inside the active V 32-block would corrupt that
    block's shared exponent on the next quantized-resident update. With
    ``n > 0`` it is the prefix-cache copy-on-write: the shared prefix is
    materialized into the new request's own page *before* its first
    suffix chunk diverges from the donor.

    Only raw K/V rows need to survive the copy bit-exactly: quantized
    mirror leaves (and any leaf without a ``cache_seq`` axis, e.g. legacy
    ``v_exps``) are zeroed outright, because the first suffix chunk step
    recomputes mirrors from the raw page in full (see the chunked-prefill
    branch in ``layers.attention.attn_apply``) before anything reads
    them. Blockwise V codes straddling the prefix boundary depend on
    donor rows beyond ``n``, so copying them would be wrong anyway.
    """
    out = []
    for seg_pool, seg_spec in zip(pool, specs):
        seg = {}
        for name, v in seg_pool.items():
            spec = seg_spec[name]
            ax = _batch_axis(spec)
            row = jnp.take(v, src_row[None], axis=ax)
            if "cache_seq" in spec and name in ("k", "v", "kv"):
                sax = spec.index("cache_seq")
                shape = [1] * v.ndim
                shape[sax] = v.shape[sax]
                idx = jnp.arange(v.shape[sax]).reshape(shape)
                row = jnp.where(idx < n, row, jnp.zeros((), v.dtype))
            else:
                row = jnp.zeros_like(row)
            seg[name] = v.at[(slice(None),) * ax + (dst_row[None],)].set(row)
        out.append(seg)
    return out


class PagedKVCache:
    """Fixed pool of KV pages + slot allocator for one served model.

    ``mx_digital`` pools carry quantized-resident K/V code mirrors next to
    the raw pages (see ``layers.attention``): decode re-quantizes only the
    written K row and active V block per step instead of the whole page.

    ``layout="fused"`` allocates the head-interleaved paged layout
    (``kernels.paged_attention.layout``): decode then runs the ragged
    paged flash-decode path directly against the pool via
    ``RunCtx.paged_rows`` — no per-step gather/scatter of full pages.
    """

    def __init__(self, cfg, num_slots: int, lanes: int, page_len: int,
                 mx_digital: bool = False, layout: str = "legacy"):
        if layout not in ("legacy", "fused"):
            raise ValueError(f"unknown KV layout {layout!r}")
        for seg in lm.build_segments(cfg):
            if seg.kind not in ("attn", "moe_attn"):
                raise NotImplementedError(
                    "paged serving requires attention-only segments "
                    f"(recurrent state can't take padded prefill): {seg.kind}"
                )
            if seg.attn.window and seg.attn.window < page_len:
                raise NotImplementedError(
                    "paged serving needs full pages (window "
                    f"{seg.attn.window} < page_len {page_len}); ring-wrap "
                    "SWA pages are future work"
                )
        self.cfg = cfg
        self.num_slots = num_slots
        self.lanes = lanes
        self.page_len = page_len
        self.mx_digital = mx_digital
        self.layout = layout
        self.fused = layout == "fused"
        self.specs = lm.cache_specs(cfg, mx_digital=mx_digital,
                                    fused=self.fused)
        self.pool = lm.init_cache(cfg, num_slots + lanes, page_len,
                                  mx_digital=mx_digital, fused=self.fused)
        self.allocator = SlotAllocator(num_slots)

    def scratch_row(self, lane: int) -> int:
        return self.num_slots + lane

    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    def gather(self, rows):
        return gather_rows(self.pool, self.specs, rows)

    def scatter(self, rows, values) -> None:
        self.pool = scatter_rows(self.pool, self.specs, rows, values)
