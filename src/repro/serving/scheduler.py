"""Continuous-batching request scheduler.

Pure host-side control: it owns the waiting queue and the lane->request
map and decides, step by step, whether the engine should run a prefill
(admit one queued request into a free lane + free slot, or continue a
chunked prefill already in flight) or a decode step over the currently
active lanes. The jitted steps themselves are fixed shape; inactive
lanes ride along parked on scratch rows.

Policies:
  ``prefill`` (prefill-prioritized, throughput-first): admit whenever a
      request is waiting and a lane and a KV slot are free — fills the
      batch as fast as possible, at the cost of stalling in-flight decodes
      for one prefill step per admission. A chunked prefill runs its
      chunks back to back.
  ``decode`` (decode-prioritized, latency-first): keep decoding while any
      lane is active; admissions (and prefill chunks) happen only when
      the engine would otherwise idle (no active lanes).
  ``chunked`` (fair interleave): while both a prefill (new admission or
      in-flight chunk sequence) and live decode lanes want the engine,
      alternate one prefill-chunk step with one decode step — long
      prompts no longer stall decode lanes for their whole prefill.

Stop conditions, checked after every generated token: ``max_new_tokens``
reached, the optional per-request ``stop_token`` sampled, or the KV page
exhausted (``pos == page_len``). Completion frees both the lane and the
KV slot (eviction), immediately re-admittable.
"""

from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    stop_token: int | None = None
    arrival: int = 0  # engine step index at which the request was added
    # runtime state (engine-owned)
    lane: int = -1
    slot: int = -1
    pos: int = 0  # next decode position == len(prompt) + len(out)
    out: list[int] = dataclasses.field(default_factory=list)
    prefill_step: int = -1  # engine step index of the (first) prefill
    finish_step: int = -1
    prefilled: int = 0  # prompt tokens already resident in the KV page
    prefix_hit: int = 0  # of which came from the prefix cache

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class Scheduler:
    def __init__(self, lanes: int, policy: str = "prefill", obs=None):
        if policy not in ("prefill", "decode", "chunked"):
            raise ValueError(f"unknown scheduling policy {policy!r}")
        self.lanes = lanes
        self.policy = policy
        self.obs = obs  # repro.obs.Obs handle (None: no telemetry)
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}  # lane -> request
        self.prefilling: Request | None = None  # mid-chunked-prefill
        self._free_lanes = list(range(lanes - 1, -1, -1))
        self._last = "idle"  # last planned action (chunked interleave)

    def _gauges(self) -> None:
        if self.obs is None or not self.obs.enabled:
            return
        reg = self.obs.registry
        reg.gauge("serve_queue_depth", "waiting requests").set(
            len(self.waiting)
        )
        reg.gauge("serve_active_lanes", "lanes decoding live work").set(
            len(self.running)
        )

    # ------------------------------------------------------------- queries

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running
                    or self.prefilling is not None)

    @property
    def num_active(self) -> int:
        return len(self.running)

    def plan(self, free_slots: int) -> str:
        """Next engine action: 'prefill' | 'decode' | 'idle'.

        ``free_slots`` is the number of KV page slots the engine could
        produce for an admission — with a prefix cache attached that
        includes evictable cached pages, not just the allocator's free
        list.
        """
        can_admit = bool(self.waiting) and bool(self._free_lanes) \
            and free_slots > 0 and self.prefilling is None
        wants_prefill = can_admit or self.prefilling is not None
        if self.policy == "chunked":
            if wants_prefill and self.running:
                action = "decode" if self._last == "prefill" else "prefill"
            elif wants_prefill:
                action = "prefill"
            else:
                action = "decode" if self.running else "idle"
        elif wants_prefill and (self.policy == "prefill"
                                or not self.running):
            action = "prefill"
        elif self.running:
            action = "decode"
        else:
            action = "idle"
        if action != "idle":
            self._last = action
        if self.obs is not None and self.obs.enabled:
            self.obs.registry.counter(
                "serve_sched_decisions_total",
                "scheduler plan() outcomes by action",
                labels={"action": action, "policy": self.policy},
            ).inc()
        return action

    # ----------------------------------------------------------- mutation

    def add(self, req: Request) -> None:
        self.waiting.append(req)
        self._gauges()

    def admit(self, slot: int, step: int) -> Request:
        """Pop the next waiting request onto a free lane with KV slot
        ``slot``. Caller (the engine) allocated the slot. Single-shot
        prefill admission: the request is immediately decodable."""
        req = self.begin_prefill(slot, step)
        self.finish_prefill(req)
        return req

    def begin_prefill(self, slot: int, step: int) -> Request:
        """Chunked admission: the request takes a lane and a slot but is
        *not* decodable yet — it sits in ``self.prefilling`` (owning its
        lane, outside ``running``) until :meth:`finish_prefill`."""
        if self.prefilling is not None:
            raise RuntimeError("a chunked prefill is already in flight")
        req = self.waiting.popleft()
        req.lane = self._free_lanes.pop()
        req.slot = slot
        req.prefill_step = step
        self.prefilling = req
        self._gauges()
        return req

    def finish_prefill(self, req: Request) -> None:
        """The whole prompt is resident: move the request onto its lane's
        decode seat."""
        if self.prefilling is req:
            self.prefilling = None
        req.pos = len(req.prompt)
        req.prefilled = len(req.prompt)
        self.running[req.lane] = req
        self._gauges()

    def finish(self, req: Request, step: int) -> None:
        """Evict a completed request: frees the lane (the engine frees the
        KV slot, which it owns via the allocator)."""
        req.finish_step = step
        del self.running[req.lane]
        self._free_lanes.append(req.lane)
        self._gauges()

    @staticmethod
    def stop_reason(req: Request, page_len: int) -> str | None:
        """Why the request stops now, or None if it keeps decoding:
        ``max_new`` (token budget reached), ``stop_token`` (sampled the
        per-request stop id), ``page_exhausted`` (KV page full — the
        eviction case). Completion reasons are checked before exhaustion
        so a request that fills its page *on* its last budgeted token
        still counts as completed; ``page_exhausted`` is reachable
        because the engine admits ``len(prompt) + max_new > page_len``
        (it used to reject those up front, which made this arm dead
        code)."""
        if len(req.out) >= req.max_new:
            return "max_new"
        if (req.stop_token is not None and req.out
                and req.out[-1] == req.stop_token):
            return "stop_token"
        if req.pos >= page_len:
            return "page_exhausted"
        return None

    @classmethod
    def stopped(cls, req: Request, page_len: int) -> bool:
        return cls.stop_reason(req, page_len) is not None


def static_batching_plan(requests: list[Request], lanes: int,
                         prefill_len: int | None = None):
    """Reference naive static batching: requests grouped ``lanes`` at a
    time; each group prefills every member, then decodes until the
    *longest* member finishes (no eviction, no backfill). Returns the same
    (kind, rids, n_tokens) event-trace format the engine emits, for the
    pipeline model's continuous-vs-static comparison.

    ``prefill_len`` bills each prefill at the executed padded width (what
    the engine's fixed-shape step actually pushes through the FWS
    pipeline); ``None`` keeps the historical per-prompt billing.
    """
    events = []
    for g in range(0, len(requests), lanes):
        group = requests[g:g + lanes]
        for r in group:
            events.append(
                ("prefill", (r.rid,),
                 len(r.prompt) if prefill_len is None else prefill_len)
            )
        steps = max(r.max_new - 1 for r in group) if group else 0
        for t in range(steps):
            live = tuple(r.rid for r in group if r.max_new - 1 > t)
            # every lane of the group occupies the pipeline whether or not
            # its request is still live — that's the waste being measured
            events.append(("decode", live, len(group)))
    return events
