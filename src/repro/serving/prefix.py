"""Radix-style prefix cache over the paged KV pool.

Shared system prompts dominate production traffic: the first D·L tokens
of most requests are identical, and the FWS premise (fixed weights, all
cost in the dynamic KV path) makes recomputing them the single biggest
avoidable cost. This module deduplicates that work at page granularity:

* The tree is a **token-chunk radix tree** — one edge per ``chunk_len``
  prompt tokens, matching the engine's fixed-shape chunked-prefill grid,
  so a cached prefix is always re-usable without recompiles. Each node
  at depth ``d`` names the page of some past request whose first ``d*L``
  tokens equal the node's path and offers its first ``d*L`` KV rows.

* Slots are shared via **refcounts** on ``SlotAllocator``: the cache
  holds one reference per slot it advertises, the engine holds one per
  in-flight request. A donor page can therefore outlive its request, and
  an LRU eviction can never pull a page out from under a live lane
  (evictable ⇔ refcount == 1 ⇔ the cache is the sole owner).

* A hit is **copy-on-write at the divergence point**: the engine copies
  the matched rows into the admitted request's own page
  (``kvcache.clone_prefix``) before its first suffix chunk runs. The
  divergence point is the match depth — decode writes begin immediately
  after prefill — so the copy happens eagerly at admission.

* Page identity is **content-addressable**: a node carries a fingerprint
  of the donor page's prefix rows — hashing the PR 4 quantized-resident
  code mirrors when the pool has them, raw K/V rows otherwise — and the
  engine re-hashes the donor at match time. A hit is therefore provably
  the same KV bytes, not just the same token ids: any corruption or
  layout drift turns into a counted miss instead of silent wrong KV.

Correctness of reuse rests on causality: row ``i`` of a page depends
only on prompt tokens ``<= i``, pages are zeroed beyond the copied
prefix at admission, and the first suffix chunk recomputes the quantized
mirrors for the whole page — so a cache-on run's pool state is bitwise a
cache-off run's, and outputs are token-identical (pinned by
tests/test_prefix.py across float/mxfp4/cim).

Blockwise V codes need care when hashing: a 32-block straddling the
prefix boundary shares its exponent with donor rows *beyond* the prefix,
so those bytes are donor-dependent. Fingerprints cover only whole
V blocks inside the prefix (K codes and raw rows are per-position and
cover the tail).
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct

import numpy as np

from repro.core import mx as mxlib


def page_rows(kv, slot: int) -> dict:
    """Pull page ``slot`` to the host in one device_get — fingerprinting
    happens on the host copy so hashing k depths costs one transfer, not
    k × leaves of them (the transfer, not the SHA, dominates)."""
    import jax
    import jax.numpy as jnp

    takes, keys = [], []
    for si, (seg_cache, seg_spec) in enumerate(zip(kv.pool, kv.specs)):
        for name in sorted(seg_cache):
            v = seg_cache[name]
            takes.append(jnp.take(v, slot, axis=seg_spec[name].index("batch")))
            keys.append((si, name))
    return dict(zip(keys, jax.device_get(takes)))


def rows_fingerprint(kv, rows: dict, n: int) -> bytes:
    """SHA-1 over the prefix-determined bytes of the first ``n`` rows of
    a host page copy (:func:`page_rows`): every leaf's prefix slice, with
    blockwise V codes/exponents truncated to whole 32-blocks (partial
    boundary blocks depend on donor rows beyond the prefix — see module
    docstring)."""
    h = hashlib.sha1()
    h.update(struct.pack("<iii", n, kv.page_len, int(kv.fused)))
    nb = (n // mxlib.BLOCK) * mxlib.BLOCK
    for si, seg_spec in enumerate(kv.specs):
        for name in sorted(seg_spec):
            arr = rows[(si, name)]
            spec = seg_spec[name]
            ax = spec.index("batch")
            sub = spec[:ax] + spec[ax + 1:]
            if name == "v_exps":
                # shared exponents, one per 32-block along the key axis:
                # legacy [Hkv, Dh, Wpad//32] (block axis last), fused
                # [ceil(W/32), Hkv, Dh] (block axis first)
                bax = 0 if kv.fused else arr.ndim - 1
                parts = [np.take(arr, np.arange(n // mxlib.BLOCK), axis=bax)]
            elif name == "v_codes":
                sax = sub.index("cache_seq")
                parts = [np.take(arr, np.arange(nb), axis=sax)]
            elif name == "kv_codes":
                # fused head-interleaved codes [W, 2*Hkv, dpad//2]: even
                # head rows are K codes (per-position, safe to n), odd
                # are V codes (blockwise, whole blocks only)
                parts = [arr[:n, 0::2], arr[:nb, 1::2]]
            elif "cache_seq" in sub:  # k, v, kv, k_codes, k_exps
                sax = sub.index("cache_seq")
                parts = [np.take(arr, np.arange(n), axis=sax)]
            else:
                continue
            h.update(name.encode())
            for p in parts:
                p = np.ascontiguousarray(p)
                h.update(struct.pack("<i", p.ndim))
                h.update(np.asarray(p.shape, np.int64).tobytes())
                h.update(p.tobytes())
    return h.digest()


def page_fingerprint(kv, slot: int, n: int) -> bytes:
    """One-shot fingerprint of rows ``[0, n)`` of page ``slot``."""
    return rows_fingerprint(kv, page_rows(kv, slot), n)


class _Node:
    __slots__ = ("children", "slot", "fp", "depth", "last_used")

    def __init__(self, depth: int):
        self.children: dict[tuple, _Node] = {}
        self.slot: int | None = None  # backing page, None = tombstone
        self.fp: bytes | None = None
        self.depth = depth
        self.last_used = 0


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    n_tokens: int  # chunk-aligned matched prefix length
    slot: int  # donor page to clone from


class PrefixCache:
    """Token-chunk radix tree mapping shared prompt prefixes to
    refcounted page slots. Host-side control plane; the engine does the
    page copies.

    ``fingerprints=False`` disables content hashing (used by the
    control-plane property tests, which run without a real KV pool).
    """

    def __init__(self, chunk_len: int, allocator, obs=None,
                 fingerprints: bool = True):
        if chunk_len < 1:
            raise ValueError("chunk_len must be >= 1")
        self.chunk_len = chunk_len
        self.allocator = allocator
        self.obs = obs
        self.fingerprints = fingerprints
        self.root = _Node(0)
        self._tick = 0
        # slot -> nodes advertising it; the cache holds ONE allocator
        # reference per distinct slot in this map
        self._slots: dict[int, set[_Node]] = {}
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0
        self.verify_failures = 0
        self.inserted = 0

    # ------------------------------------------------------------ helpers

    def _count(self, name: str, by: int = 1) -> None:
        if self.obs is not None and self.obs.enabled:
            self.obs.registry.counter(
                f"serve_prefix_{name}_total",
                f"prefix cache {name.replace('_', ' ')}",
            ).inc(by)

    def _chunks(self, prompt, depth: int):
        L = self.chunk_len
        return tuple(prompt[(depth - 1) * L:depth * L])

    def _drop_slot(self, slot: int) -> None:
        """Forget every node backed by ``slot`` and release the cache's
        reference (tombstoning keeps deeper nodes reachable)."""
        for node in self._slots.pop(slot, ()):
            node.slot = None
            node.fp = None
        self.allocator.release(slot)

    # ------------------------------------------------------------- queries

    @property
    def cached_slots(self) -> set[int]:
        return set(self._slots)

    @property
    def n_evictable(self) -> int:
        """Cached pages no live request also holds (refcount 1 ⇒ the
        cache is the sole owner and may free them on demand)."""
        return sum(1 for s in self._slots if self.allocator.refcount(s) == 1)

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "hit_tokens": self.hit_tokens,
            "evictions": self.evictions,
            "verify_failures": self.verify_failures,
            "inserted": self.inserted,
            "cached_slots": len(self._slots),
        }

    # ------------------------------------------------------------ mutation

    def match(self, prompt, kv=None) -> PrefixHit | None:
        """Longest chunk-aligned cached prefix of ``prompt``, capped at
        ``len(prompt) - 1`` tokens: at least one suffix token always runs
        live, so the admitted request still emits its first token from a
        real prefill chunk (which also rebuilds the page's quantized
        mirrors). Verifies the donor page's fingerprint before declaring
        a hit."""
        self._tick += 1
        max_depth = (len(prompt) - 1) // self.chunk_len
        node, best = self.root, None
        for d in range(1, max_depth + 1):
            node = node.children.get(self._chunks(prompt, d))
            if node is None:
                break
            if node.slot is not None:
                best = node
        if best is None:
            self.misses += 1
            self._count("misses")
            return None
        if self.fingerprints and kv is not None:
            fp = page_fingerprint(kv, best.slot, best.depth * self.chunk_len)
            if fp != best.fp:
                # the bytes under the advertised page changed — integrity
                # failure, not a routine miss; drop the backing slot
                self.verify_failures += 1
                self.misses += 1
                self._count("verify_failures")
                self._count("misses")
                self._drop_slot(best.slot)
                return None
        best.last_used = self._tick
        n = best.depth * self.chunk_len
        self.hits += 1
        self.hit_tokens += n
        self._count("hits")
        self._count("hit_tokens", n)
        return PrefixHit(n_tokens=n, slot=best.slot)

    def insert(self, prompt, slot: int, kv=None) -> bool:
        """Offer a freshly prefilled page to the cache. Nodes are created
        for every full chunk of ``prompt``; nodes that already advertise
        a (verified-identical, by the causality argument) page keep their
        existing backing. Returns True if the cache adopted ``slot`` (and
        took an allocator reference on it)."""
        self._tick += 1
        max_depth = len(prompt) // self.chunk_len
        node, adopted = self.root, False
        rows = (page_rows(kv, slot)
                if self.fingerprints and kv is not None and max_depth else None)
        for d in range(1, max_depth + 1):
            key = self._chunks(prompt, d)
            child = node.children.get(key)
            if child is None:
                child = node.children[key] = _Node(d)
            if child.slot is None:
                child.slot = slot
                child.fp = (rows_fingerprint(kv, rows, d * self.chunk_len)
                            if rows is not None else None)
                if not adopted:
                    self.allocator.retain(slot)
                    adopted = True
                self._slots.setdefault(slot, set()).add(child)
            child.last_used = self._tick
            node = child
        if adopted:
            self.inserted += 1
            self._count("inserts")
        return adopted

    def evict_lru(self) -> bool:
        """Free the least-recently-used evictable page (refcount 1). The
        freed slot lands back on the allocator's free list; tombstoned
        nodes keep deeper, differently-backed paths reachable. Returns
        False when nothing is evictable."""
        victims = [
            (max(n.last_used for n in nodes), slot)
            for slot, nodes in self._slots.items()
            if self.allocator.refcount(slot) == 1
        ]
        if not victims:
            return False
        _, slot = min(victims)
        self._drop_slot(slot)
        self.evictions += 1
        self._count("evictions")
        return True
