"""Single-stream image-throughput serving for encoder (ViT) workloads.

The paper's headline numbers (Table 7) are frames-per-second figures for
vision encoders on the twelve-stage FWS pipeline — this module makes them
*measured* instead of closed-form: a :class:`VisionEngine` streams frames
one at a time through one fixed-shape jitted forward (encoders have no KV
cache and no decode step, so the whole serving problem is a feed-forward
pipeline), records the per-frame stage traffic (token count = patch grid
+ CLS), and maps that measured traffic onto the
``serving/pipeline.py`` discrete-event model of the §5.3 pipeline.

Dual-chip workloads (vit-l32: 24 blocks split 12+12, paper §5.3) run the
trunk as a chip chain — ``vit.split_chips`` slices the layer-stacked
params with ``distributed.sharding.stage_partition``, each chip owns its
own jitted step, and the hidden-state handoff between chips is the
inter-chip hop that ``pipeline.simulate(chips=2)`` bills as an extra
link stage (``perf.t_interchip``).

``fws_report(workload=...)`` cross-validates: the engine's *measured*
token traffic drives the pipeline at the named workload's hardware shape
(d_model, chip count) and the steady-state FPS must land on the paper's
Table 7 row (checked within 5% in tests/test_vision.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.hwmodel import specs as S
from repro.models import vit
from repro.serving import pipeline as pipe_mod


@dataclasses.dataclass(frozen=True)
class VisionReport:
    pipeline: pipe_mod.PipelineReport
    fps: float  # steady-state frames/s of the FWS pipeline model
    frame_latency_s: float  # one frame through the full (multi-chip) pipe
    n_tokens: int  # measured stage traffic per frame
    d_model: int  # hardware width the pipeline was billed at
    chips: int
    paper_fps: float | None = None  # Table 7 row, when cross-validating

    @property
    def fps_error(self) -> float | None:
        if not self.paper_fps:
            return None
        return abs(self.fps - self.paper_fps) / self.paper_fps

    def publish(self, registry, prefix: str = "pipeline") -> None:
        """Export the FWS pipeline gauges plus the vision-specific frame
        latency (and paper cross-check, when present) into a registry."""
        self.pipeline.publish(registry, prefix=prefix)
        registry.gauge(
            f"{prefix}_frame_latency_seconds",
            "one frame through the full (multi-chip) pipeline",
        ).set(self.frame_latency_s)
        if self.paper_fps:
            registry.gauge(
                f"{prefix}_paper_fps_error",
                "relative error vs the paper's Table 7 row",
            ).set(self.fps_error)


class VisionEngine:
    """Fixed-shape single-stream frame engine over the backend registry.

    Works under any linear-execution backend (float / mxfp4 / cim): the
    jitted steps just call ``vit.forward`` / ``vit.forward_chip`` with
    whatever converted params + RunCtx the caller built.
    """

    def __init__(self, params, cfg: vit.ViTConfig, ctx, chips: int | None = None,
                 obs=None, runner=None):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.chips = chips or cfg.chips
        self.obs = obs if obs is not None else obs_mod.Obs()
        self._next_fid = 0
        self.runner = runner  # distributed.pipeline_exec.StagePipeline
        if runner is not None:
            # real stage-parallel execution on a device mesh: frames run in
            # pipelined slabs of runner.capacity, the chip chain is unused
            self.chips = runner.n_stages
            self._chain = []
        elif self.chips == 1:
            self._chain = [(
                jax.jit(lambda p, img: vit.forward(p, cfg, ctx,
                                                   {"images": img})[0]),
                params, None,
            )]
        else:
            self._chain = []
            chip_trees = vit.split_chips(params, cfg, self.chips)
            for ci, (chip_params, n_layers) in enumerate(chip_trees):
                first = ci == 0
                last = ci == self.chips - 1

                def step(p, x, n=n_layers, first=first, last=last):
                    return vit.forward_chip(p, cfg, ctx, x, n, first, last)

                self._chain.append((jax.jit(step), chip_params, n_layers))

    # --------------------------------------------------------- execution

    def classify_frame(self, image: jax.Array) -> int:
        """One frame [H, W, C] through the chip chain; returns the top-1
        class and records the frame's stage traffic as a typed event."""
        if self.runner is not None:
            return self._stream_pipelined(jnp.asarray(image)[None])[0]
        t0 = self.obs.clock()
        x = jnp.asarray(image)[None]  # fixed shape [1, H, W, C]
        for fn, chip_params, _ in self._chain:
            x = fn(chip_params, x)  # hidden handoff == inter-chip hop
        logits = np.asarray(jax.device_get(x), np.float32)[0]
        fid = self._next_fid
        self._next_fid += 1
        self.obs.step_recorded("frame", (fid,), self.cfg.seq_len,
                               t0, self.obs.clock())
        if self.obs.enabled:
            self.obs.registry.counter(
                "vision_frames_total", "frames streamed"
            ).inc()
        return int(logits.argmax())

    @property
    def trace(self) -> list:
        """Derived view: n_tokens per streamed frame (the measured stage
        traffic), rebuilt from the typed frame events."""
        return [e.n_tokens for e in self.obs.steps if e.kind == "frame"]

    def stream(self, frames) -> list[int]:
        """Stream frames ([N, H, W, C] or iterable of [H, W, C]): one at a
        time through the chip chain (single-stream serving, the Table 7
        operating mode), or — with a stage-parallel ``runner`` — in
        pipelined slabs of overlapping microbatches on the device mesh."""
        if self.runner is not None:
            return self._stream_pipelined(jnp.asarray(frames))
        return [self.classify_frame(f) for f in frames]

    def _stream_pipelined(self, frames: jax.Array) -> list[int]:
        out: list[int] = []
        cap = self.runner.capacity
        for i in range(0, frames.shape[0], cap):
            chunk = frames[i:i + cap]
            t0 = self.obs.clock()
            logits = jax.device_get(self.runner.forward({"images": chunk}))
            t1 = self.obs.clock()
            n = chunk.shape[0]
            for j in range(n):
                fid = self._next_fid
                self._next_fid += 1
                # bill each frame an equal slice of the slab wall so the
                # derived trace keeps one event per frame
                self.obs.step_recorded(
                    "frame", (fid,), self.cfg.seq_len,
                    t0 + (t1 - t0) * j / n, t0 + (t1 - t0) * (j + 1) / n,
                )
            if self.obs.enabled:
                self.obs.registry.counter(
                    "vision_frames_total", "frames streamed"
                ).inc(n)
            out.extend(
                int(v.argmax()) for v in np.asarray(logits, np.float32)
            )
        return out

    def measured_report(self, frames, reps: int = 3):
        """Measured pipeline health from real multi-device runs (requires
        a stage-parallel runner): per-stage walls, occupancy, bubble —
        the hardware-measured counterpart of :meth:`fws_report`."""
        if self.runner is None:
            raise ValueError("measured_report needs a pipelined runner")
        batch = jnp.asarray(frames)[: self.runner.capacity]
        return self.runner.measure({"images": batch}, reps=reps)

    # ----------------------------------------------------------- reports

    def fws_report(self, workload: str | None = None,
                   min_frames: int = 240) -> VisionReport:
        """Map the measured per-frame stage traffic onto the FWS pipeline.

        ``workload`` names a ``hwmodel.specs.WORKLOADS`` entry to
        cross-validate against: the pipeline is billed at that workload's
        hardware shape (d_model, chips) — the engine may run a width-tiny
        but geometry-true model — and the measured token count must match
        the workload's. The measured trace is tiled up to ``min_frames``
        jobs so the pipeline reaches steady state.
        """
        if not self.trace:
            raise ValueError("no frames streamed yet")
        d_model, chips, paper_fps = self.cfg.d_model, self.chips, None
        if workload is not None:
            w = S.WORKLOADS[workload]
            if w.seq != self.cfg.seq_len:
                raise ValueError(
                    f"measured stage traffic ({self.cfg.seq_len} tokens) "
                    f"!= workload {workload!r} ({w.seq} tokens)"
                )
            d_model, chips = w.d, w.chips
            if workload in S.PAPER_TABLE7:
                paper_fps = S.PAPER_TABLE7[workload][1]
            elif workload in S.PAPER_TABLE9:
                paper_fps = S.PAPER_TABLE9[workload]
        trace = list(self.trace)
        while len(trace) < min_frames:
            trace.extend(self.trace)
        rep = pipe_mod.simulate(
            [pipe_mod.Job(0.0, n) for n in trace], d_model, chips=chips
        )
        return VisionReport(
            pipeline=rep,
            fps=rep.steady_state_fps,
            frame_latency_s=rep.timings[0].latency,
            n_tokens=self.trace[0],
            d_model=d_model,
            chips=chips,
            paper_fps=paper_fps,
        )


def synthetic_stream_report(n_tokens: int, d_model: int, chips: int = 1,
                            n_frames: int = 240,
                            paper_fps: float | None = None) -> VisionReport:
    """FWS pipeline report for traffic-shaped-only streams (no executable
    model run): e.g. bert-base-shaped traffic (N=512 jobs) or full-size
    Table 7 rows where only the (N, d, chips) shape matters."""
    rep = pipe_mod.simulate(
        [pipe_mod.Job(0.0, n_tokens) for _ in range(n_frames)],
        d_model, chips=chips,
    )
    return VisionReport(
        pipeline=rep,
        fps=rep.steady_state_fps,
        frame_latency_s=rep.timings[0].latency,
        n_tokens=n_tokens,
        d_model=d_model,
        chips=chips,
        paper_fps=paper_fps,
    )
