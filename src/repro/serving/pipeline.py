"""Discrete-event model of the paper's §5.3 twelve-stage FWS pipeline.

MXFormer statically partitions the model's twelve transformer blocks over
twelve chip blocks; a scheduled batch (a prefill of N prompt tokens, or
one decode step over B active lanes = B tokens) streams through the
stages in order. Each stage holds a job for
``perf.stage_time(n_tokens, d_model)`` = max(T_analog, T_digital): the
analog CTT arrays consume one token per BITPLANES*MUX*PASSES = 20 analog
cycles while the two 32x64 systolic arrays run the tile-quantized
attention matmuls, and the slower side bounds the stage.

The simulator is a plain in-order, non-preemptive event model: job j
enters stage k at ``max(job j leaves stage k-1, stage k free)``. Once all
stages are occupied one job drains per ``stage_time`` — the steady-state
throughput must match ``perf.steady_state_fps`` and, for the paper's
encoder workloads, the Table 7 FPS figures (checked within 5% in
tests/test_serving.py and tests/test_vision.py).

Multi-chip deployments (``chips > 1``: vit-l32 / bert-large split their
24 blocks 12+12 over two chips) chain ``chips`` copies of the
``n_stages`` compute stages with one inter-chip hop stage between
consecutive chips (``perf.t_interchip``: the [N, d] bf16 activation tile
crossing the link). The hop deepens the pipeline — more fill latency —
but never bounds steady-state throughput for the paper's shapes.

``simulate_trace`` maps the serving engine's (kind, rids, n_tokens) event
trace onto the pipeline and attributes per-request latency: a request is
live from the entry of its prefill job to the drain of the last job that
carried one of its tokens.
"""

from __future__ import annotations

import dataclasses

from repro.hwmodel import perf

N_STAGES = 12  # transformer blocks per die (hwmodel.specs.SystemSpec)


@dataclasses.dataclass(frozen=True)
class Job:
    arrival: float  # seconds; jobs are served FIFO in arrival order
    n_tokens: int
    tag: object = None


@dataclasses.dataclass(frozen=True)
class JobTiming:
    job: Job
    start: float  # entry into stage 0
    finish: float  # drain out of the last stage

    @property
    def latency(self) -> float:
        return self.finish - self.job.arrival


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    timings: list
    makespan: float
    stage_utilization: float  # busy fraction of one stage over makespan
    analog_utilization: float  # analog busy fraction *within* busy time
    digital_utilization: float
    fps: float  # jobs drained / makespan
    steady_state_fps: float  # tail-window throughput (pipeline full)

    @property
    def bubble_fraction(self) -> float:
        """Idle (bubble) fraction of a compute stage over the makespan —
        the complement of ``stage_utilization``: fill/drain ramps and
        host-side admission gaps show up here."""
        return max(0.0, 1.0 - self.stage_utilization)

    @property
    def fill_latency_s(self) -> float:
        """Time for the first job to traverse the empty pipeline (stage
        depth x stage time + hops) — the pipeline-fill cost every burst
        pays once."""
        if not self.timings:
            return 0.0
        t0 = self.timings[0]
        return t0.finish - t0.start

    def publish(self, registry, prefix: str = "pipeline") -> None:
        """Export stage occupancy / bubble / fill-latency gauges into a
        ``repro.obs.MetricsRegistry``."""
        g = registry.gauge
        g(f"{prefix}_stage_occupancy",
          "busy fraction of one FWS compute stage").set(
            self.stage_utilization)
        g(f"{prefix}_bubble_fraction",
          "idle (bubble) fraction of one FWS compute stage").set(
            self.bubble_fraction)
        g(f"{prefix}_analog_utilization",
          "analog busy fraction within stage busy time").set(
            self.analog_utilization)
        g(f"{prefix}_digital_utilization",
          "digital busy fraction within stage busy time").set(
            self.digital_utilization)
        g(f"{prefix}_fill_latency_seconds",
          "first job through the empty pipeline").set(self.fill_latency_s)
        g(f"{prefix}_steady_state_fps",
          "tail-window drain rate with the pipeline full").set(
            self.steady_state_fps)
        g(f"{prefix}_makespan_seconds", "simulated makespan").set(
            self.makespan)


def simulate(jobs: list, d_model: int, n_stages: int = N_STAGES,
             warmup: int | None = None, chips: int = 1,
             stage_time_fn=None, hop_time_fn=None) -> PipelineReport:
    """Run ``jobs`` (FIFO by list order) through the pipeline.

    With ``chips > 1`` the stage chain is ``chips`` copies of the
    ``n_stages`` compute stages separated by one inter-chip hop stage each
    (``perf.t_interchip``); utilization accounting covers the compute
    stages only (the hop is link occupancy, not array occupancy).

    ``stage_time_fn(n_tokens, d_model, stage_index) -> seconds`` overrides
    the CTT hardware model's per-stage service time — this is how the real
    multi-device executor's *measured* per-stage walls drive the
    discrete-event model for cross-validation (the CPU host cannot agree
    with the hardware model in absolute time, but the schedule must; see
    ``benchmarks/run.py::pipeline_multidevice``). ``hop_time_fn(n_tokens,
    d_model) -> seconds`` likewise overrides ``perf.t_interchip``. The
    analog/digital utilization split is a hardware-model quantity and
    reports 0 under an override.
    """
    if not jobs:
        return PipelineReport([], 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
    total_stages = chips * n_stages + (chips - 1)
    hop_at = set(
        c * (n_stages + 1) + n_stages for c in range(chips - 1)
    )  # stage indices occupied by the inter-chip link
    free_at = [0.0] * total_stages
    timings = []
    busy = 0.0
    t_analog_busy = 0.0
    t_digital_busy = 0.0
    n_compute = chips * n_stages
    for job in jobs:
        if stage_time_fn is None:
            stage_times = [perf.stage_time(job.n_tokens, d_model)] * n_compute
        else:
            stage_times = [
                float(stage_time_fn(job.n_tokens, d_model, k))
                for k in range(n_compute)
            ]
        if chips > 1:
            t_hop = (hop_time_fn or perf.t_interchip)(job.n_tokens, d_model)
        else:
            t_hop = 0.0
        t = max(job.arrival, free_at[0])
        start = t
        ci = 0
        for k in range(total_stages):
            if k in hop_at:
                t_k = t_hop
            else:
                t_k = stage_times[ci]
                ci += 1
            t = max(t, free_at[k])
            free_at[k] = t + t_k
            t = t + t_k
        timings.append(JobTiming(job, start, t))
        busy += sum(stage_times) / n_compute  # mean per compute stage
        if stage_time_fn is None:
            t_analog_busy += perf.t_analog(job.n_tokens)
            t_digital_busy += perf.t_digital(job.n_tokens, d_model)
    makespan = max(x.finish for x in timings)
    # steady state: drain spacing once the pipeline is full
    warmup = total_stages if warmup is None else warmup
    warmup = min(warmup, len(timings) - 1)
    tail = timings[warmup:]
    span = tail[-1].finish - timings[warmup - 1].finish if warmup else None
    ss_fps = len(tail) / span if span else len(timings) / makespan
    return PipelineReport(
        timings=timings,
        makespan=makespan,
        stage_utilization=busy / makespan if makespan else 0.0,
        analog_utilization=t_analog_busy / busy if busy else 0.0,
        digital_utilization=t_digital_busy / busy if busy else 0.0,
        fps=len(timings) / makespan if makespan else 0.0,
        steady_state_fps=ss_fps,
    )


@dataclasses.dataclass(frozen=True)
class TraceReport:
    pipeline: PipelineReport
    request_latency: dict  # rid -> seconds (prefill entry -> last token out)
    tokens_per_s: float  # generated tokens drained / makespan
    lane_utilization: float  # live lanes / (lanes * decode steps)

    def publish(self, registry, prefix: str = "pipeline") -> None:
        """Export the pipeline gauges plus trace-level throughput and the
        simulated per-request latency histogram into a registry."""
        self.pipeline.publish(registry, prefix=prefix)
        registry.gauge(
            f"{prefix}_tokens_per_s",
            "generated tokens drained per simulated second",
        ).set(self.tokens_per_s)
        registry.gauge(
            f"{prefix}_lane_utilization",
            "live lanes / (lanes * decode steps)",
        ).set(self.lane_utilization)
        h = registry.histogram(
            f"{prefix}_sim_request_latency_seconds",
            "simulated request latency (prefill entry -> last token out)",
        )
        for v in self.request_latency.values():
            h.observe(v)


def simulate_trace(events: list, d_model: int, lanes: int,
                   n_stages: int = N_STAGES) -> TraceReport:
    """Map an engine event trace onto the pipeline.

    ``events``: list of (kind, rids, n_tokens) tuples or typed
    ``repro.obs.StepEvent`` records — kind 'prefill' (one request's
    padded prompt) or 'decode' (one token for each rid; for the
    static-batching reference n_tokens may exceed len(rids): dead lanes
    still occupy the hardware). Jobs all arrive at t=0 back-to-back — the
    host scheduler is assumed to keep the pipeline fed.
    """
    events = [
        (e.kind, e.rids, e.n_tokens) if hasattr(e, "kind") else e
        for e in events
    ]
    jobs = [Job(0.0, n, (kind, rids)) for kind, rids, n in events]
    rep = simulate(jobs, d_model, n_stages)
    first_in: dict = {}
    last_out: dict = {}
    n_generated = 0
    live = 0
    decode_steps = 0
    for timing in rep.timings:
        kind, rids = timing.job.tag
        for rid in rids:
            first_in.setdefault(rid, timing.start)
            last_out[rid] = timing.finish
        if kind == "prefill":
            n_generated += 1  # prefill emits the first token
        else:
            n_generated += len(rids)
            live += len(rids)
            decode_steps += 1
    latency = {rid: last_out[rid] - first_in[rid] for rid in first_in}
    return TraceReport(
        pipeline=rep,
        request_latency=latency,
        tokens_per_s=n_generated / rep.makespan if rep.makespan else 0.0,
        lane_utilization=(
            live / (lanes * decode_steps) if decode_steps else 1.0
        ),
    )
