"""Feed-forward variants: gelu / squared-relu MLPs and swiglu / geglu GLUs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import RunCtx, linear_apply, linear_init, norm_apply, norm_init

GLU_KINDS = ("swiglu", "geglu")


def _act(kind: str, x: jax.Array) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":  # Nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if kind == "swiglu":
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def ffn_init(key, d: int, d_ff: int, kind: str, norm: str, use_bias: bool = False):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln"], s["ln"] = norm_init(norm, d)
    p["w1"], s["w1"] = linear_init(ks[0], d, d_ff, use_bias=use_bias, out_axis="mlp")
    if kind in GLU_KINDS:
        p["w3"], s["w3"] = linear_init(ks[1], d, d_ff, use_bias=use_bias, out_axis="mlp")
    p["w2"], s["w2"] = linear_init(
        ks[2], d_ff, d, use_bias=use_bias, in_axis="mlp", out_axis="embed"
    )
    return p, s


def ffn_apply(ctx: RunCtx, kind: str, norm: str, p: dict, x: jax.Array) -> jax.Array:
    """Pre-norm FFN sublayer with residual."""
    xn = norm_apply(norm, p["ln"], x)
    h = _act(kind, linear_apply(ctx, p["w1"], xn, name="w1"))
    if kind in GLU_KINDS:
        h = h * linear_apply(ctx, p["w3"], xn, name="w3")
    h = ctx.act(h, "batch", "seq", "mlp")
    y = linear_apply(ctx, p["w2"], h, name="w2")
    y = ctx.act(y, "batch", "seq", "embed")
    return x + y.astype(x.dtype)
