"""Rotary position embeddings: standard RoPE and Qwen2-VL style M-RoPE."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions [..., S] -> angles [..., S, dim//2] (f32)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def rope_tables(
    positions: jax.Array, dim: int, theta: float = 1e4
) -> tuple[jax.Array, jax.Array]:
    """(cos, sin) f32 [B, S, 1, dim//2] for :func:`apply_rope`'s
    ``tables``. The tables depend only on positions, so callers compute
    them once per forward and share them across q/k and scanned layers —
    otherwise XLA re-materializes the sin/cos transcendentals into every
    consumer fusion (measured as the top cost of the quantized forward)."""
    ang = _rope_angles(positions, dim, theta)  # [B, S, dim//2]
    return jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 1e4,
    rotary_dim: int | None = None,
    tables: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int32. Rotates the first
    ``rotary_dim`` features (half-split convention). ``tables`` passes
    precomputed :func:`rope_tables` (f32; cast here, so the values are
    bitwise the inline computation)."""
    d = x.shape[-1]
    rd = d if rotary_dim is None else rotary_dim
    if tables is None:
        tables = rope_tables(positions, rd, theta)
    cos = tables[0].astype(x.dtype)  # [B, S, 1, rd//2]
    sin = tables[1].astype(x.dtype)
    x1, x2 = x[..., : rd // 2], x[..., rd // 2 : rd]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot, x[..., rd:]], axis=-1) if rd < d else rot


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 1e6,
    sections=(16, 24, 24),
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: [3, B, S] (temporal, h, w)
    component position ids; ``sections`` are half-dim splits per component
    (sum == head_dim // 2)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang3 = positions.astype(jnp.float32)[..., None] * inv  # [3, B, S, d//2]
    # pick which component drives each frequency band
    comp = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang3, 0, -1), comp[None, None, :, None], axis=-1
    )[..., 0]  # [B, S, d//2]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Text-only M-RoPE position ids: all three components equal."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
