"""xLSTM blocks: mLSTM (matrix memory, exponential gating) and sLSTM
(scalar memory, block-diagonal recurrence). Sequential lax.scan over time
(compact HLO; a chunkwise-parallel mLSTM is a §Perf candidate).

Static projections take the MXFP4 path; the matrix-memory outer products
are dynamic compute (digital-path analogue, DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.layers.common import (
    RunCtx,
    linear_apply,
    linear_init,
    norm_apply,
    norm_init,
    rmsnorm_apply,
)


@dataclasses.dataclass(frozen=True)
class XLSTMStatic:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0  # mLSTM up-projection factor
    conv_k: int = 4
    norm: str = "rmsnorm"
    ffn_factor: float = 4.0 / 3.0  # sLSTM post-FFN

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def s_head_dim(self) -> int:
        return self.d_model // self.n_heads


# ------------------------------------------------------------------ mLSTM

def mlstm_init(key, cfg: XLSTMStatic):
    ks = jax.random.split(key, 8)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    p, s = {}, {}
    p["ln"], s["ln"] = norm_init(cfg.norm, d)
    p["w_up"], s["w_up"] = linear_init(ks[0], d, 2 * di, out_axis="mlp")
    p["conv_w"] = jax.random.normal(ks[1], (di, cfg.conv_k), jnp.float32) * 0.5
    s["conv_w"] = ("mlp", "conv")
    p["conv_b"] = jnp.zeros((di,), jnp.float32)
    s["conv_b"] = ("mlp",)
    p["wq"], s["wq"] = linear_init(ks[2], di, di, in_axis="mlp", out_axis="mlp")
    p["wk"], s["wk"] = linear_init(ks[3], di, di, in_axis="mlp", out_axis="mlp")
    p["wv"], s["wv"] = linear_init(ks[4], di, di, in_axis="mlp", out_axis="mlp")
    p["w_if"], s["w_if"] = linear_init(ks[5], di, 2 * h, in_axis="mlp",
                                       out_axis="heads")
    p["gn"], s["gn"] = norm_init("rmsnorm", di)
    p["skip"] = jnp.ones((di,), jnp.float32)
    s["skip"] = ("mlp",)
    p["w_down"], s["w_down"] = linear_init(ks[6], di, d, in_axis="mlp",
                                           out_axis="embed")
    return p, s


def _mlstm_step(carry, inp, scale):
    cm, nm, mm = carry  # C [b,h,dk,dv], n [b,h,dk], m [b,h]
    q, k, v, ig, fg = inp  # q/k/v [b,h,dk|dv], ig/fg [b,h]
    m_new = jnp.maximum(fg + mm, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + mm - m_new)
    cm = f_p[..., None, None] * cm + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    nm = f_p[..., None] * nm + i_p[..., None] * k
    hn = jnp.einsum("bhkv,bhk->bhv", cm, q) * scale
    dn = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", nm, q) * scale), 1.0)
    h_t = hn / dn[..., None]
    return (cm, nm, m_new), h_t


def _mlstm_chunkwise(qf, kf, vf, ig, fg, init, scale, chunk: int = 64,
                     unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM, exactly equivalent to scanning
    :func:`_mlstm_step` (tested): the running stabilizer satisfies
    m_t = F_t + G_t with F_t = cumsum(log f) and
    G_t = max(m_prev, cummax(i~_j - F_j)), so all exp(F_i) factors cancel
    and each chunk reduces to two masked matmuls + an O(S/L) state scan.
    This removes the per-step C-matrix read/write traffic that made
    sequential xLSTM memory-bound (EXPERIMENTS.md §Perf).

    qf/kf/vf: [b,s,h,dk] (kf pre-scaled); ig/fg: [b,s,h] (fg=log sigmoid).
    init: (C [b,h,dk,dv], n [b,h,dk], m [b,h]). Returns (h [b,s,h,dv],
    (C,n,m) final).
    """
    b, s, h, dk = qf.shape
    ll = min(chunk, s)
    pad = (-s) % ll
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        qf, kf, vf = (jnp.pad(a, z4) for a in (qf, kf, vf))
        ig = jnp.pad(ig, z3, constant_values=-1e30)  # no input
        fg = jnp.pad(fg, z3)  # log f = 0: no decay
    nc = (s + pad) // ll

    def chunkf(carry, xs):
        c_prev, n_prev, m_prev = carry
        q, k, v, igc, fgc = xs  # [b,L,h,*]
        f_cum = jnp.cumsum(fgc, axis=1)  # [b,L,h]
        u = igc - f_cum
        g = jnp.maximum(m_prev[:, None], jax.lax.cummax(u, axis=1))
        dlog = u[:, None, :, :] - g[:, :, None, :]  # [b,i,j,h]
        tri = jnp.tril(jnp.ones((ll, ll), bool))[None, :, :, None]
        w = jnp.exp(jnp.where(tri, dlog, -jnp.inf))
        sij = jnp.einsum("bihd,bjhd->bijh", q, k)
        sw = sij * w
        num = jnp.einsum("bijh,bjhd->bihd", sw, v)
        den = jnp.sum(sw, axis=2)  # [b,i,h]
        c_i = jnp.exp(m_prev[:, None] - g)  # [b,L,h]
        num = num + c_i[..., None] * jnp.einsum("bhkv,bihk->bihv", c_prev, q)
        den = den + c_i * jnp.einsum("bhk,bihk->bih", n_prev, q)
        hout = num * scale / jnp.maximum(
            jnp.abs(den * scale), 1.0
        )[..., None]
        # end-of-chunk state
        g_l = g[:, -1]  # [b,h]
        wj = jnp.exp(u - g_l[:, None])  # [b,L,h]
        cc = jnp.exp(m_prev - g_l)
        c_new = cc[..., None, None] * c_prev + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", wj, k, v
        )
        n_new = cc[..., None] * n_prev + jnp.einsum("bjh,bjhd->bhd", wj, k)
        m_new = f_cum[:, -1] + g_l
        return (c_new, n_new, m_new), hout

    xs = tuple(
        a.reshape((b, nc, ll) + a.shape[2:]).swapaxes(0, 1)
        for a in (qf, kf, vf, ig, fg)
    )
    carry, hs = jax.lax.scan(chunkf, init, xs, unroll=nc if unroll else 1)
    hout = hs.swapaxes(0, 1).reshape(b, nc * ll, h, -1)[:, :s]
    return hout, carry


def mlstm_apply(ctx: RunCtx, cfg: XLSTMStatic, p: dict, x: jax.Array,
                cache: dict | None = None):
    b, s, d = x.shape
    h, dk = cfg.n_heads, cfg.head_dim
    di = cfg.d_inner
    xn = norm_apply(cfg.norm, p["ln"], x)
    up = linear_apply(ctx, p["w_up"], xn)
    xi, z = up[..., :di], up[..., di:]

    kk = cfg.conv_k
    prefill = cache is None or s > 1
    if prefill:
        padded = jnp.pad(xi, ((0, 0), (kk - 1, 0), (0, 0)))
        conv = sum(
            padded[:, i : i + s, :] * p["conv_w"][:, i] for i in range(kk)
        )
        tail = xi[:, -(kk - 1) :].astype(jnp.float32)
        if s < kk - 1:
            tail = jnp.pad(tail, ((0, 0), (kk - 1 - s, 0), (0, 0)))
        new_conv = tail.swapaxes(1, 2) if cache is not None else None
    else:
        win = jnp.concatenate(
            [cache["conv"], xi.astype(jnp.float32).swapaxes(1, 2)], axis=-1
        )
        conv = jnp.sum(win * p["conv_w"][None], axis=-1)[:, None]
        new_conv = win[..., 1:]
    conv = jax.nn.silu(conv + p["conv_b"].astype(conv.dtype))

    q = linear_apply(ctx, p["wq"], conv).reshape(b, s, h, dk)
    k = linear_apply(ctx, p["wk"], conv).reshape(b, s, h, dk)
    v = xi.reshape(b, s, h, dk)
    gates = linear_apply(ctx, p["w_if"], conv).astype(jnp.float32)
    ig, fg = gates[..., :h], jax.nn.log_sigmoid(gates[..., h:])
    scale = dk**-0.5

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) * scale
    vf = v.astype(jnp.float32)
    if prefill:
        init = (
            jnp.zeros((b, h, dk, dk), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )
        if cache is not None:
            init = (cache["C"], cache["n"], cache["m"])
        ht, (cmf, nmf, mmf) = _mlstm_chunkwise(
            qf, kf, vf, ig, fg, init, scale, unroll=ctx.unroll_scans
        )
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv, "C": cmf, "n": nmf, "m": mmf}
    else:
        carry = (cache["C"], cache["n"], cache["m"])
        carry, h1 = _mlstm_step(
            carry, (qf[:, 0], kf[:, 0], vf[:, 0], ig[:, 0], fg[:, 0]), scale
        )
        ht = h1[:, None]
        new_cache = {"conv": new_conv, "C": carry[0], "n": carry[1], "m": carry[2]}

    hflat = ht.reshape(b, s, di)
    hflat = rmsnorm_apply(p["gn"], hflat) + p["skip"] * conv.astype(jnp.float32)
    out = hflat.astype(jnp.bfloat16) * jax.nn.silu(z)
    y = linear_apply(ctx, p["w_down"], out)
    y = ctx.act(y, "batch", "seq", "embed")
    return x + y.astype(x.dtype), new_cache


def mlstm_cache_init(cfg: XLSTMStatic, batch: int):
    h, dk = cfg.n_heads, cfg.head_dim
    return {
        "conv": jnp.zeros((batch, cfg.d_inner, cfg.conv_k - 1), jnp.float32),
        "C": jnp.zeros((batch, h, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -jnp.inf, jnp.float32),
    }


MLSTM_CACHE_SPECS = {
    "conv": ("batch", "mlp", "conv"),
    "C": ("batch", "state_heads", None, None),
    "n": ("batch", "state_heads", None),
    "m": ("batch", "state_heads"),
}


# ------------------------------------------------------------------ sLSTM

def slstm_init(key, cfg: XLSTMStatic):
    ks = jax.random.split(key, 4)
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.s_head_dim
    p, s = {}, {}
    p["ln"], s["ln"] = norm_init(cfg.norm, d)
    p["w_in"], s["w_in"] = linear_init(ks[0], d, 4 * d, out_axis="mlp")
    p["r"] = jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32) * (dh**-0.5)
    s["r"] = ("heads", "head_dim", "mlp")
    p["gn"], s["gn"] = norm_init("rmsnorm", d)
    dff = int(d * cfg.ffn_factor)
    p["w_up"], s["w_up"] = linear_init(ks[2], d, 2 * dff, out_axis="mlp")
    p["w_down"], s["w_down"] = linear_init(ks[3], dff, d, in_axis="mlp",
                                           out_axis="embed")
    return p, s


def _slstm_step(carry, wx_t, r):
    c, n, m, hp = carry  # [b,h,dh] each
    rec = jnp.einsum("bhd,hde->bhe", hp, r)  # [b,h,4*dh]
    pre = wx_t + rec
    dh = c.shape[-1]
    zi, ii, ff, oo = jnp.split(pre, 4, axis=-1)
    ff = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(ff + m, ii)
    i_p = jnp.exp(ii - m_new)
    f_p = jnp.exp(ff + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(zi)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(oo) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_apply(ctx: RunCtx, cfg: XLSTMStatic, p: dict, x: jax.Array,
                cache: dict | None = None):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.s_head_dim
    xn = norm_apply(cfg.norm, p["ln"], x)
    wx = linear_apply(ctx, p["w_in"], xn).astype(jnp.float32)
    wx = wx.reshape(b, s, h, 4 * dh)

    if cache is None or s > 1:
        z0 = jnp.zeros((b, h, dh), jnp.float32)
        init = (z0, z0, jnp.full((b, h, dh), -jnp.inf, jnp.float32), z0)
        if cache is not None:
            init = (cache["c"], cache["n"], cache["m"], cache["h"])
        carry, hs = jax.lax.scan(
            lambda c, i: _slstm_step(c, i, p["r"]), init, wx.transpose(1, 0, 2, 3)
        )
        ht = hs.transpose(1, 0, 2, 3).reshape(b, s, d)
        new_cache = (
            {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
            if cache is not None
            else None
        )
    else:
        carry = (cache["c"], cache["n"], cache["m"], cache["h"])
        carry, h1 = _slstm_step(carry, wx[:, 0], p["r"])
        ht = h1.reshape(b, 1, d)
        new_cache = {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}

    y1 = rmsnorm_apply(p["gn"], ht).astype(x.dtype)
    x = x + y1
    # post sLSTM FFN (GeGLU, pf = 4/3)
    up = linear_apply(ctx, p["w_up"], x)
    dff = up.shape[-1] // 2
    y2 = linear_apply(ctx, p["w_down"], jax.nn.gelu(up[..., :dff]) * up[..., dff:])
    y2 = ctx.act(y2, "batch", "seq", "embed")
    return x + y2.astype(x.dtype), new_cache


def slstm_cache_init(cfg: XLSTMStatic, batch: int):
    h, dh = cfg.n_heads, cfg.s_head_dim
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, dh), -jnp.inf), "h": z}


SLSTM_CACHE_SPECS = {
    "c": ("batch", "state_heads", None),
    "n": ("batch", "state_heads", None),
    "m": ("batch", "state_heads", None),
    "h": ("batch", "state_heads", None),
}
