"""Pluggable linear-execution backends (the paper's hybrid partition).

Every quantized-linear in the model stack executes through a *backend*
registered here instead of an inline ``ctx.quant`` string-``if`` chain.
A backend owns three things:

- ``forward(ctx, params, x)``: the matmul numerics (pure-jnp reference and
  a Pallas implementation selected by ``ctx.impl``, with the kernel
  ``interpret`` flag threaded from ``ctx.interpret``),
- ``convert(params, ...)``: the offline serving transform of one linear
  param node (e.g. packed MXFP4 codes, or resident INT5 codes + exps +
  Row-Hist calibration for the analog CTT array),
- ``handles(params)``: the converted-param marker, so serving trees
  dispatch by what is resident rather than by context string.

Registered backends:

==================  =======================================================
``float_bf16``      unquantized BF16 matmul (training/eval baseline)
``mxfp4_ste``       QAT fake-quant of weights + activations (STE)
``mxfp4_ste_prequant``  activations fake-quantized per call; weights were
                    fake-quantized once at the step boundary
``mxfp4_wonly``     weight-only packed MXFP4 (4.25 b/param FWS serving)
``cim_analog``      analog CTT-CIM array: resident INT5 codes, per-block
                    exponents, Row-Hist ``LayerCalib`` (paper §3, §5.2.2)
==================  =======================================================

``ctx.quant`` aliases: ``"none" -> float_bf16``, ``"cim" -> cim_analog``.
Unknown names raise ``ValueError`` (no silent float fallthrough).

The hybrid analog/digital split (paper §4): *static* dense linears
(QKV/O projections, FFN up/gate/down, shared-block projections, LM head)
convert to ``cim_analog`` resident arrays; *dynamic* compute (SDPA, MoE
expert dispatch) stays on the digital MXFP4 path.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cimlib
from repro.core import mx as mxlib


# ----------------------------------------------------------- param packing

# byte -> two bf16 code values; shared with the paged-attention kernel's
# in-tile KV dequant (repro.kernels.paged_attention), which decodes the
# same nibble packing inside VMEM
_PAIR_TABLE = mxlib.PAIR_TABLE


def _dequant_packed(codes: jax.Array, exps: jax.Array) -> jax.Array:
    """packed uint8 codes [K//2, N] + biased exps [K//32, N] -> bf16 [K, N].

    All-bf16 arithmetic: codes/2 and 2^e are exactly representable in
    bf16, so this is bit-identical to the f32 path while cutting the
    dequant intermediate traffic ~3x (decode is weight-read bound —
    EXPERIMENTS.md §Perf; the Pallas kernel removes even this by
    expanding inside VMEM). Each byte decodes through the u32 pair table
    (:data:`repro.core.mx.PAIR_TABLE`) in one gather."""
    kp2, n = codes.shape[-2], codes.shape[-1]
    k = kp2 * 2
    pair = jnp.asarray(_PAIR_TABLE)[codes.astype(jnp.int32)]  # [..., K//2, N]
    u16 = jax.lax.bitcast_convert_type(pair, jnp.uint16)  # [..., 2] LE: 0=lo
    cb = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)
    cb = jnp.swapaxes(cb, -1, -2).reshape(codes.shape[:-2] + (k, n))
    scale = mxlib.exp2i(mxlib.exps_from_biased(exps) - 1).astype(
        jnp.bfloat16
    )  # 2^(e-1) == 0.5 * 2^e, exact
    w = cb.reshape(codes.shape[:-2] + (k // 32, 32, n)) * scale[..., :, None, :]
    return w.reshape(codes.shape[:-2] + (k, n))


def _quantize_packed(w: jax.Array) -> dict:
    """[..., K, N] float -> packed MXFP4 {codes [..., K//2, N] uint8,
    exps [..., K//32, N] uint8} quantized along K."""
    mxq = mxlib.quantize(jnp.swapaxes(w, -1, -2))
    codes = jnp.swapaxes(mxq.codes, -1, -2)
    packed = jnp.swapaxes(
        mxlib.pack_codes(jnp.swapaxes(codes, -1, -2)), -1, -2
    )
    exps = mxlib.exps_to_biased(jnp.swapaxes(mxq.exps, -1, -2))
    return {"codes": packed, "exps": exps}


def quantize_linear_params(params: dict) -> dict:
    """Convert a float linear param dict to packed MXFP4 (weight-only)."""
    out = _quantize_packed(params["w"])
    if "b" in params:
        out["b"] = params["b"]
    return out


# --------------------------------------------------------------- registry

class LinearBackend:
    """Base class: one linear-execution strategy."""

    name = "?"

    def handles(self, params: dict) -> bool:
        """True if ``params`` is this backend's converted serving node."""
        return False

    def convert(self, params: dict, **kw) -> dict:
        return params

    def forward(self, ctx, params: dict, x: jax.Array) -> jax.Array:
        raise NotImplementedError


_REGISTRY: dict[str, LinearBackend] = {}
# "mxfp4_digital" is the fully-digital MXFP4 accelerator eval mode: W+A
# fake-quant linears (same numerics as the STE training forward) plus the
# digital MXFP4 SDPA — the apples-to-apples baseline for the hybrid
# analog path (RunCtx.hybrid_digital_sdpa covers both).
_ALIASES = {
    "none": "float_bf16",
    "cim": "cim_analog",
    "mxfp4_digital": "mxfp4_ste",
}


def register_backend(backend: LinearBackend) -> LinearBackend:
    _REGISTRY[backend.name] = backend
    return backend


def backend_names() -> list[str]:
    return sorted(_REGISTRY)


def get_backend(name: str) -> LinearBackend:
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise ValueError(
            f"unknown linear-execution backend {name!r}; known: "
            f"{backend_names()} (aliases {sorted(_ALIASES)})"
        )
    return _REGISTRY[key]


def resolve_backend(ctx, params: dict) -> LinearBackend:
    """Converted-param markers win (what is resident in the array decides
    execution); otherwise ``ctx.quant`` names the backend. Raises
    ``ValueError`` on an unknown name."""
    for marker in ("cim_analog", "mxfp4_wonly"):
        b = _REGISTRY[marker]
        if b.handles(params):
            return b
    return get_backend(ctx.quant)


def cim_config(ctx) -> cimlib.CIMConfig:
    """The CIM array config for this run (paper operating point when the
    context does not override it: 10b ADC, CM=3, Row-Hist 2-pass)."""
    return ctx.cim if getattr(ctx, "cim", None) is not None else cimlib.CIMConfig()


# --------------------------------------------------------------- backends

def _register(cls):
    register_backend(cls())
    return cls


@_register
class _FloatBF16(LinearBackend):
    name = "float_bf16"

    def forward(self, ctx, params, x):
        return jnp.matmul(x.astype(jnp.bfloat16), params["w"].astype(jnp.bfloat16))


@_register
class _MXFP4STE(LinearBackend):
    name = "mxfp4_ste"

    def forward(self, ctx, params, x):
        wq = mxlib.fake_quant_axis(params["w"], axis=0)
        xq = mxlib.fake_quant(x.astype(jnp.float32))
        return jnp.matmul(xq.astype(jnp.bfloat16), wq.astype(jnp.bfloat16))


@_register
class _MXFP4STEPrequant(LinearBackend):
    name = "mxfp4_ste_prequant"

    def forward(self, ctx, params, x):
        # weights were fake-quantized once at the step boundary (exact:
        # weights are constant within a step) — gathers move bf16 instead
        # of f32 and the quant ops run once, not k_micro times
        xq = mxlib.fake_quant(x.astype(jnp.float32))
        return jnp.matmul(xq.astype(jnp.bfloat16), params["w"].astype(jnp.bfloat16))


@_register
class _MXFP4WeightOnly(LinearBackend):
    name = "mxfp4_wonly"

    def handles(self, params):
        return "codes" in params and "e_n" not in params

    def convert(self, params, **kw):
        return quantize_linear_params(params)

    def forward(self, ctx, params, x):
        if "codes" not in params:
            # not yet converted (eval on a float tree): weight-only quant
            # happens at convert time, so this is the plain bf16 matmul
            return _REGISTRY["float_bf16"].forward(ctx, params, x)
        if ctx.use_pallas:
            from repro.kernels.mxfp4_matmul import ops as mmops

            return mmops.mxfp4_matmul(
                x, params["codes"], params["exps"], interpret=ctx.interpret,
                obs=ctx.obs,
            )
        w = _dequant_packed(params["codes"], params["exps"])
        return jnp.matmul(x.astype(jnp.bfloat16), w)


@_register
class _CIMAnalog(LinearBackend):
    """Analog CTT-CIM array execution of a static linear.

    Converted node layout (all jax arrays, scan-stackable along a leading
    layer axis): ``codes`` int8 [K, N] signed INT5 weight codes resident in
    the array, ``exps`` int8 [K//32, N] per-block weight exponents,
    ``e_n`` int32 [] Row-Hist target exponent, ``adc_fs`` f32 [] calibrated
    ADC full scale, optional ``b`` (digital bias add after read-out).
    """

    name = "cim_analog"

    def handles(self, params):
        return "e_n" in params

    def convert(self, params, calib: cimlib.LayerCalib,
                wq: mxlib.MXW | None = None, **kw):
        # the converted node is independent of the CIMConfig operating
        # point — only the LayerCalib (computed under a config) carries it
        if wq is None:
            wq = mxlib.quantize_w(params["w"].astype(jnp.float32))
        out = {
            "codes": wq.codes,
            "exps": wq.exps,
            "e_n": jnp.asarray(calib.e_n, jnp.int32),
            "adc_fs": jnp.asarray(calib.adc_fs, jnp.float32),
        }
        if "b" in params:
            out["b"] = params["b"].astype(jnp.bfloat16)
        return out

    def forward(self, ctx, params, x):
        if "e_n" not in params:
            # hybrid partition: linears without a resident analog copy
            # (uncalibrated / too small, e.g. routers and SSM projections)
            # execute on the digital MXFP4 W+A path — same numerics as the
            # fully-digital baseline, so hybrid-vs-digital deltas isolate
            # the analog layers
            return _REGISTRY["mxfp4_ste"].forward(ctx, params, x)
        cfg = cim_config(ctx)
        w = mxlib.MXW(params["codes"], params["exps"])
        calib = cimlib.LayerCalib(e_n=params["e_n"], adc_fs=params["adc_fs"])
        if ctx.use_pallas:
            from repro.kernels.cim_linear import ops as cim_ops

            y = cim_ops.cim_linear(
                x, w, calib, cfg=cfg, interpret=ctx.interpret, obs=ctx.obs
            )
        else:
            y, _ = cimlib.cim_linear(x, w, cfg, calib)
        return y.astype(jnp.bfloat16)


# --------------------------------------------------- MoE expert weights

def expert_weight(ctx, w) -> jax.Array:
    """Resolve a stacked [E, K, N] expert weight for the digital expert
    einsum. MoE experts stay on the digital MXFP4 path under every backend
    (expert dispatch is dynamic — the paper's hybrid partition keeps only
    static-weight linears in the analog array). Validates ``ctx.quant``
    against the registry, so unknown names raise instead of silently
    running float."""
    if isinstance(w, dict):  # serving-converted packed MXFP4
        return jax.vmap(_dequant_packed)(w["codes"], w["exps"])
    backend = get_backend(ctx.quant)  # raises on unknown backend names
    if backend.name in ("mxfp4_ste", "cim_analog"):
        # digital MXFP4 W+A numerics; under the hybrid backend an
        # unconverted expert bank must still quantize digitally so
        # hybrid-vs-digital deltas isolate the analog layers
        w = mxlib.fake_quant_axis(w, axis=1)
    # "mxfp4_ste_prequant": already quantized at the step boundary
    return w.astype(jnp.bfloat16)


# --------------------------------------------------- Row-Hist calibration

@dataclasses.dataclass
class ActivationTap:
    """Records per-linear input activations during an *eager* capture run.

    ``linear_apply`` calls :meth:`record` with the param-tree path of the
    linear (built from ``RunCtx.scoped`` scopes + the call-site name) when a
    tap is active on the context. Only static analog candidates are kept:
    2-D weights with a 32-aligned contraction dim and a wide-enough output
    dim. Rows are subsampled to ``max_rows`` per call to bound memory.

    Captures stay *on device*: ``record`` only slices/casts (async under
    the eager capture run — no ``jax.device_get`` host sync per linear per
    batch mid-forward); the single host transfer happens when
    ``calibrate_taps`` consumes the records.
    """

    min_n: int = 256
    max_rows: int = 512
    records: dict = dataclasses.field(default_factory=dict)
    weights: dict = dataclasses.field(default_factory=dict)
    # also capture at already-converted serving nodes (resident CIM codes
    # or packed MXFP4) — the SQNR tracer runs the same tap over the
    # converted tree and compares captures path-by-path against a float
    # reference run; calibration keeps the default (float-only) gate
    include_converted: bool = False

    def _in_dim(self, params) -> int | None:
        """Contraction dim of a capturable linear node, else None."""
        if not isinstance(params, dict):
            return None
        w = params.get("w")
        if getattr(w, "ndim", 0) == 2:
            k, n = w.shape
            return k if k % mxlib.BLOCK == 0 and n >= self.min_n else None
        if not self.include_converted:
            return None
        c = params.get("codes")
        if getattr(c, "ndim", 0) != 2:
            return None
        # cim_analog: int8 codes [K, N]; mxfp4_wonly: packed nibble pairs
        # [K//2, N]
        k = c.shape[0] * (1 if "e_n" in params else 2)
        return k if c.shape[1] >= self.min_n else None

    def eligible(self, params) -> bool:
        return self._in_dim(params) is not None

    def record(self, path: str, params: dict, x: jax.Array) -> None:
        k = self._in_dim(params)
        if k is None:
            return
        xf = x.astype(jnp.float32).reshape(-1, k)
        if xf.shape[0] > self.max_rows:
            # deterministic in shape: ref and instrumented runs of the
            # same batch subsample identical rows, so captures compare
            idx = np.linspace(0, xf.shape[0] - 1, self.max_rows).astype(int)
            xf = jnp.take(xf, jnp.asarray(idx), axis=0)
        self.records.setdefault(path, []).append(xf)
        if "w" in params:
            self.weights[path] = params["w"]


def calibrate_taps(
    tap: ActivationTap,
    cfg: cimlib.CIMConfig | None = None,
    wq_cache: dict | None = None,
) -> dict[str, cimlib.LayerCalib]:
    """Offline Row-Hist calibration (paper §3.2.1) of every tapped linear:
    per-layer target exponent E_N + ADC full scale from the recorded
    representative activations. The records arrive as device arrays (the
    tap never host-syncs mid-forward) and feed the jitted calibration
    passes directly — no host round-trip at all. Pass a dict as
    ``wq_cache`` to receive the quantized MXW per path, so conversion
    skips re-quantizing."""
    cfg = cfg or cimlib.CIMConfig()
    out = {}
    for path, xs in tap.records.items():
        wq = mxlib.quantize_w(
            jnp.asarray(tap.weights[path]).astype(jnp.float32)
        )
        if wq_cache is not None:
            wq_cache[path] = wq
        out[path] = cimlib.calibrate_rowhist(list(xs), wq, cfg)
    return out


def _stacked_keys(path: str, n_layers: int) -> list[str]:
    """Capture keys for a layer-stacked param node: the unrolled capture
    run scopes each layer as ``segments/<i>/L<j>/...`` while the stacked
    tree path is ``segments/<i>/...``."""
    parts = path.split("/")
    if len(parts) < 2 or parts[0] != "segments":
        return []
    return [
        "/".join(parts[:2] + [f"L{j}"] + parts[2:]) for j in range(n_layers)
    ]


def convert_params_cim(
    tree,
    calibs: dict[str, cimlib.LayerCalib],
    min_n: int = 256,
    wq_cache: dict | None = None,
):
    """Serving transform for the hybrid analog/digital deployment.

    Static linears with Row-Hist calibration (keyed by param-tree path,
    from :func:`calibrate_taps`) become resident ``cim_analog`` nodes —
    INT5 codes + block exponents + per-layer calib, stacked along the layer
    axis for scanned segments so ``lax.scan`` slices per-layer calibration
    exactly like the weights. MoE expert banks become packed digital MXFP4
    (dynamic dispatch stays digital); everything else is cast to bf16.
    """
    cim = _REGISTRY["cim_analog"]
    wq_cache = wq_cache or {}

    def convert_stacked(node, path):
        w = node["w"]
        n_layers = w.shape[0]
        keys = _stacked_keys(path, n_layers)
        if not keys or not all(k in calibs for k in keys):
            return None
        per = []
        for j, key in enumerate(keys):
            nj = {"w": w[j]}
            if "b" in node:
                nj["b"] = node["b"][j]
            per.append(cim.convert(nj, calibs[key],
                                   wq=wq_cache.get(key)))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def rec(node, path):
        if isinstance(node, dict):
            w = node.get("w")
            if (
                getattr(w, "ndim", 0) == 2
                and w.shape[0] % mxlib.BLOCK == 0
                and w.shape[1] >= min_n
                and path in calibs
            ):
                return cim.convert(node, calibs[path],
                                   wq=wq_cache.get(path))
            if (
                getattr(w, "ndim", 0) == 3
                and w.shape[1] % mxlib.BLOCK == 0
                and w.shape[2] >= min_n
            ):
                conv = convert_stacked(node, path)
                if conv is not None:
                    return conv
            out = {}
            for k, v in node.items():
                if (
                    k in ("w1", "w2", "w3")
                    and getattr(v, "ndim", 0) in (3, 4)
                    and v.shape[-2] % mxlib.BLOCK == 0
                ):
                    out[k] = _quantize_packed(v)  # digital FWS experts
                else:
                    out[k] = rec(v, f"{path}/{k}" if path else k)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(
                rec(v, f"{path}/{i}" if path else str(i))
                for i, v in enumerate(node)
            )
        if hasattr(node, "dtype") and node.dtype == jnp.float32:
            return node.astype(jnp.bfloat16)
        return node

    return rec(tree, "")
