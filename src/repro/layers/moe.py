"""Sort-based top-k Mixture-of-Experts (Mixtral / Qwen3-MoE style).

Dispatch is sort-and-scatter with a static per-expert capacity — no
[T, E, C] one-hot einsum (which is quadratic in sequence length) — so the
compiled FLOPs track the *active* parameter count, as required for honest
roofline accounting. Tokens overflowing an expert's capacity are dropped
(standard GShard semantics); capacity_factor controls the slack.

Expert sharding is rule-driven: "experts" -> mesh axis for EP (many small
experts, e.g. qwen3 128e), "expert_mlp" -> mesh axis for TP-within-expert
(few big experts, e.g. mixtral 8e).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.layers import backends
from repro.layers.common import RunCtx, linear_init, norm_init, norm_apply
from repro.layers.ffn import GLU_KINDS, _act


def moe_init(
    key,
    d: int,
    d_ff: int,
    n_experts: int,
    kind: str,
    norm: str,
):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln"], s["ln"] = norm_init(norm, d)
    p["router"], s["router"] = linear_init(ks[0], d, n_experts, out_axis="replicated")
    scale = (1.0 / d) ** 0.5
    p["w1"] = jax.random.normal(ks[1], (n_experts, d, d_ff), jnp.float32) * scale
    s["w1"] = ("experts", "embed", "expert_mlp")
    if kind in GLU_KINDS:
        p["w3"] = jax.random.normal(ks[2], (n_experts, d, d_ff), jnp.float32) * scale
        s["w3"] = ("experts", "embed", "expert_mlp")
    p["w2"] = jax.random.normal(ks[3], (n_experts, d_ff, d), jnp.float32) * (
        1.0 / d_ff
    ) ** 0.5
    s["w2"] = ("experts", "expert_mlp", "embed")
    return p, s


def _expert_w(ctx: RunCtx, p: dict, name: str) -> jax.Array:
    """Expert weights execute on the digital path under every backend
    (dynamic dispatch — paper's hybrid partition); the registry validates
    ``ctx.quant`` so unknown backend names raise."""
    return backends.expert_weight(ctx, p[name])


def _n_groups(ctx: RunCtx, t: int) -> int:
    """Dispatch groups == data-parallel shards, so sort/gather/scatter stay
    shard-local (a flat sort over the sharded token axis is unshardable and
    XLA would replicate it, all-reducing [T*k, d] tensors)."""
    g = 1
    if ctx.shd.mesh is not None:
        for a in ("pod", "data"):
            if a in ctx.shd.mesh.axis_names:
                g *= ctx.shd.mesh.shape[a]
    while t % g:
        g //= 2
    return max(g, 1)


def moe_apply(
    ctx: RunCtx,
    kind: str,
    norm: str,
    p: dict,
    x: jax.Array,
    top_k: int,
    capacity_factor: float = 1.25,
) -> jax.Array:
    b, s, d = x.shape
    e = p["router"]["w"].shape[-1]
    t = b * s
    g = _n_groups(ctx, t)
    tg = t // g
    xn = norm_apply(norm, p["ln"], x).reshape(g, tg, d)
    if ctx.quant in ("mxfp4_ste", "mxfp4_ste_prequant"):
        xn = mxlib.fake_quant(xn)  # dtype-preserving: bf16 cotangents
    xn = ctx.act(xn, "exp_group", "seq", "embed")

    logits = (xn.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)  # [G, tg, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    cap = int(max(1, -(-tg * top_k * capacity_factor // e)))
    fe = idx.reshape(g, tg * top_k)
    order = jnp.argsort(fe, axis=-1)  # stable, per group
    se = jnp.take_along_axis(fe, order, axis=-1)
    # first occurrence of each expert in the sorted list, per group
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="left")
    )(se)  # [G, E]
    pos_in_e = jnp.arange(tg * top_k)[None] - jnp.take_along_axis(
        starts, se, axis=-1
    )
    keep = pos_in_e < cap
    dest = jnp.where(keep, se * cap + pos_in_e, 0)  # dropped -> masked add
    src_tok = order // top_k  # [G, tg*k]

    gi = jnp.arange(g)[:, None]
    xs = jnp.take_along_axis(xn, src_tok[..., None], axis=1)  # [G, tg*k, d]
    buf = jnp.zeros((g, e * cap, d), xn.dtype).at[gi, dest].add(
        xs * keep[..., None].astype(xn.dtype)
    )
    buf = buf.reshape(g, e, cap, d)
    # keep E replicated over `model` here: the scatter that builds buf is
    # local per data shard; sharding E now would force XLA to all-gather
    # the [G, tg*k, d] updates (measured 16 GiB/block on qwen3) — the
    # expert einsum below slices E locally instead.
    buf = ctx.act(buf, "exp_group", "exp_e", "exp_cap", "embed")

    w1 = _expert_w(ctx, p, "w1")
    h = jnp.einsum("gecd,edf->gecf", buf, w1)
    h = _act(kind, h)
    if kind in GLU_KINDS:
        h = h * jnp.einsum("gecd,edf->gecf", buf, _expert_w(ctx, p, "w3"))
    h = ctx.act(h, "exp_group", "experts", "exp_cap", "expert_mlp")
    if ctx.quant in ("mxfp4_ste", "mxfp4_ste_prequant"):
        h = mxlib.fake_quant(h)
    out_buf = jnp.einsum("gecf,efd->gecd", h, _expert_w(ctx, p, "w2"))
    # gather E back to replicated for the (shard-local) combine
    out_buf = ctx.act(out_buf, "exp_group", "exp_e", "exp_cap", "embed")

    flat_out = out_buf.reshape(g, e * cap, d)
    gathered = jnp.where(
        keep[..., None],
        jnp.take_along_axis(flat_out, jnp.clip(dest, 0, e * cap - 1)[..., None],
                            axis=1),
        0.0,
    )  # [G, tg*k, d] in sorted order
    gates_sorted = jnp.take_along_axis(gate.reshape(g, tg * top_k), order,
                                       axis=-1)
    contrib = gathered * gates_sorted[..., None].astype(gathered.dtype)
    y = jnp.zeros((g, tg, d), x.dtype).at[gi, src_tok].add(
        contrib.astype(x.dtype)
    )
    y = y.reshape(b, s, d)
    y = ctx.act(y, "batch", "seq", "embed")
    return x + y
