"""Grouped-query attention with RoPE/M-RoPE, causal/sliding-window/
local:global masking, KV-cache decode, and a memory-efficient
online-softmax (FlashAttention-style) path for long sequences.

This is the model-level attention; the paper's digital-stage numerics
simulator lives in ``repro.core.digital`` and the TPU kernel in
``repro.kernels.flash_attention``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.kernels.paged_attention import layout as paged_layout
from repro.kernels.paged_attention import ops as paged_ops
from repro.layers import rope as ropelib
from repro.layers.common import (
    RunCtx,
    linear_apply,
    linear_init,
    norm_apply,
    norm_init,
    rmsnorm_apply,
)


@dataclasses.dataclass(frozen=True)
class AttnStatic:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    causal: bool = True
    window: int = 0  # 0 = full attention; >0 = sliding window
    rope_theta: float = 1e4
    use_rope: bool = True
    mrope: bool = False
    qk_norm: bool = False
    use_bias: bool = False
    norm: str = "rmsnorm"
    softmax_scale: float | None = None

    @property
    def scale(self) -> float:
        return (
            self.head_dim**-0.5
            if self.softmax_scale is None
            else self.softmax_scale
        )


def attn_init(key, cfg: AttnStatic):
    ks = jax.random.split(key, 5)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p, s = {}, {}
    p["ln"], s["ln"] = norm_init(cfg.norm, d)
    p["wq"], s["wq"] = linear_init(
        ks[0], d, h * hd, use_bias=cfg.use_bias, out_axis="qkv_fused"
    )
    p["wk"], s["wk"] = linear_init(
        ks[1], d, kv * hd, use_bias=cfg.use_bias, out_axis="kv_fused"
    )
    p["wv"], s["wv"] = linear_init(
        ks[2], d, kv * hd, use_bias=cfg.use_bias, out_axis="kv_fused"
    )
    p["wo"], s["wo"] = linear_init(
        ks[3], h * hd, d, use_bias=cfg.use_bias, in_axis="qkv_fused",
        out_axis="embed",
    )
    if cfg.qk_norm:
        p["qn"], s["qn"] = norm_init("rmsnorm", hd)
        p["kn"], s["kn"] = norm_init("rmsnorm", hd)
    return p, s


# Sentinel position marking padded K/V entries (fixed-shape serving
# prefill, flash-attention tile padding). Any key whose position is at or
# below the sentinel threshold is excluded from attention unconditionally —
# a plain causal mask (kp <= qp) would otherwise *include* large-negative
# pad positions for every query.
KV_PAD = -(10**9)
_KV_PAD_MIN = KV_PAD // 2


def _mask(q_pos, k_pos, causal: bool, window: int):
    """bool [..., Sq, Sk]; True = attend. Keys at KV_PAD never attend."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.broadcast_to(kp > _KV_PAD_MIN,
                         jnp.broadcast_shapes(qp.shape, kp.shape))
    if causal:
        m = m & (kp <= qp)
    if window > 0:
        m = m & (kp > qp - window)
    return m


# Digital MXFP4 systolic SDPA quantization (paper §4.4-4.5), shared by the
# dense, flash and decode paths so the hybrid numerics stay in one place.

def _mx_qk(q, k):
    """Quantize Q/K along the head_dim contraction (last axis). bf16
    inputs run the bf16-native chain (same quantize decisions — the input
    is already bf16 — without a f32 round-trip)."""
    return _mx_fq(q), _mx_fq(k)


def _mx_fq(t):
    if t.dtype == jnp.bfloat16:
        return mxlib.fake_quant(t)
    return mxlib.fake_quant(t.astype(jnp.float32))


def _mx_score_round(s):
    """BF16 systolic accumulator round of the QK^T scores."""
    return s.astype(jnp.bfloat16).astype(jnp.float32)


def _mx_pv(p, v):
    """Re-quantize P (last axis) and V (key axis 1) before the SV array.
    Returns (p_q, v_q, den): outputs must be divided by ``den`` — the sum
    of the *quantized* probabilities, i.e. the hardware normalizer block
    (same deferred-division semantics as ``core/digital.mx_attention`` and
    the flash path), so quantizing P introduces no systematic row scale."""
    p, den = _mx_p(p)
    vq = (mxlib.fake_quant_axis(v, 1) if v.dtype == jnp.bfloat16
          else mxlib.fake_quant_axis(v.astype(jnp.float32), 1))
    return p, vq, den


def _mx_p(p):
    """Quantize P along the key axis + the hardware normalizer sum."""
    p = mxlib.fake_quant(p)
    den = jnp.sum(p, axis=-1, keepdims=True)
    den = jnp.where(den == 0.0, 1.0, den)
    return p, den


# Quantized-resident KV cache (digital-SDPA decode). The requant-per-step
# reference quantizes the *entire* K cache along head_dim and the entire V
# cache along the key axis on every decode step — O(cache_len) quantize
# work per token. But K rows quantize per-row independently (a row's codes
# only change when the row is rewritten) and V's shared-exponent 32-blocks
# along the key axis only change when a write lands inside them; so the
# cache can keep codes + exponents *resident* and re-quantize only the
# written K row and the active V block per step — O(1) in cache length,
# bitwise identical to the reference.
#
# Layouts: K codes [B, W, Hkv, Dh_pad] quantized along head_dim (exps
# [B, W, Hkv, Dh_pad//32]); V codes [B, Hkv, Dh, W_pad] with the *key*
# axis last (exps [B, Hkv, Dh, W_pad//32]) so the quantized axis is the
# contiguous block axis in both.

def _quant_cache_sizes(w: int, hd: int):
    dpad = -(-hd // mxlib.BLOCK) * mxlib.BLOCK
    wpad = -(-w // mxlib.BLOCK) * mxlib.BLOCK
    return dpad, wpad


def quant_cache_init(batch: int, w: int, n_kv: int, hd: int) -> dict:
    """Quantized mirrors for a zero-initialized K/V cache: zero blocks
    quantize to zero codes with the E8M0 floor exponent."""
    dpad, wpad = _quant_cache_sizes(w, hd)
    return {
        "k_codes": jnp.zeros((batch, w, n_kv, dpad), jnp.int8),
        "k_exps": jnp.full(
            (batch, w, n_kv, dpad // mxlib.BLOCK), mxlib.E8M0_MIN, jnp.int8
        ),
        "v_codes": jnp.zeros((batch, n_kv, hd, wpad), jnp.int8),
        "v_exps": jnp.full(
            (batch, n_kv, hd, wpad // mxlib.BLOCK), mxlib.E8M0_MIN, jnp.int8
        ),
    }


def _quant_cache_full(kw: jax.Array, vw: jax.Array) -> dict:
    """Quantize a whole cache-shaped K/V pair (prefill-into-cache):
    K per row along head_dim, V along the key axis in 32-blocks."""
    kq = mxlib.quantize(kw.astype(jnp.float32))
    vq = mxlib.quantize_axis(vw.astype(jnp.float32), 1)  # key axis last
    return {"k_codes": kq.codes, "k_exps": kq.exps,
            "v_codes": vq.codes, "v_exps": vq.exps}


def _quant_cache_step(cache: dict, ck: jax.Array, cv: jax.Array,
                      lanes: jax.Array, slot: jax.Array) -> dict:
    """Per-step resident update: re-quantize the written K row and the
    active 32-block of V (from the just-updated raw caches ``ck``/``cv``),
    leaving every other block's codes untouched — they are bitwise what a
    full requant would recompute."""
    b, w = cv.shape[0], cv.shape[1]
    kq = mxlib.quantize(ck[lanes, slot].astype(jnp.float32))  # [B, Hkv, *]
    out = {
        "k_codes": cache["k_codes"].at[lanes, slot].set(kq.codes),
        "k_exps": cache["k_exps"].at[lanes, slot].set(kq.exps),
    }
    start = (slot // mxlib.BLOCK) * mxlib.BLOCK  # [B]
    idx = start[:, None] + jnp.arange(mxlib.BLOCK)  # [B, 32]
    blk = jnp.take_along_axis(
        cv, jnp.minimum(idx, w - 1)[:, :, None, None], axis=1
    )
    blk = jnp.where((idx < w)[:, :, None, None], blk, 0)  # partial end block
    vq = mxlib.quantize_axis(blk.astype(jnp.float32), 1)  # [B, Hkv, Dh, 32]
    out["v_codes"] = jax.vmap(
        lambda c, u, st: jax.lax.dynamic_update_slice(c, u, (0, 0, st))
    )(cache["v_codes"], vq.codes, start)
    out["v_exps"] = cache["v_exps"].at[
        lanes, :, :, slot // mxlib.BLOCK
    ].set(vq.exps[..., 0])
    return out


def _dense_attn(
    q, k, v, q_pos, k_pos, cfg: AttnStatic, extra_mask=None,
    mx_digital: bool = False,
):
    """q [B,Sq,Hkv,G,Dh]; k,v [B,Sk,Hkv,Dh].

    With ``mx_digital`` the SDPA runs on the paper's digital MXFP4
    systolic datapath (core/digital.py numerics): Q/K quantized along the
    head_dim contraction, BF16 score accumulation, P/V re-quantized along
    the key contraction before the SV array. This is the hybrid backend's
    dynamic stage — weights live in the analog array, SDPA stays digital.
    """
    if mx_digital:
        q, k = _mx_qk(q, k)
        # MXFP4 values are exactly bf16-representable (4-bit mantissa),
        # so the systolic operands move as bf16 with f32 accumulation —
        # half the GEMM traffic, bitwise the same scores
        q, k = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * cfg.scale
    if mx_digital:
        s = _mx_score_round(s)
    m = _mask(q_pos, k_pos, cfg.causal, cfg.window)[:, None, None]
    if extra_mask is not None:
        m &= extra_mask[:, None, None]
    s = jnp.where(m, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isfinite(s).any(-1, keepdims=True), p, 0.0)
    if mx_digital:
        p, v, den = _mx_pv(p, v)
        o = jnp.einsum(
            "bhgqk,bkhd->bqhgd", p.astype(jnp.bfloat16),
            v.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
        # deferred division by the quantized-P sum *after* the SV array —
        # the hardware normalizer block (core/digital.mx_attention and the
        # flash path do the same), and O(q*d) divides instead of O(q*k)
        o = o / jnp.moveaxis(den, -2, 1)
        return o.astype(jnp.bfloat16)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _flash_attn(q, k, v, q_pos, k_pos, cfg: AttnStatic, ctx: RunCtx,
                mx_digital: bool = False):
    """Online-softmax attention, chunked over Q (lax.map) and KV (scan).
    Compiles to compact HLO and bounds live score memory to
    [B, qc, Hkv, G, kc]. Same tiling scheme as the Pallas kernel.

    With ``mx_digital`` (hybrid / fully-digital MXFP4 eval) Q/K are
    quantized along the head_dim contraction, scores get the BF16 systolic
    round, and P/V are re-quantized per KV tile along the key contraction —
    the same per-tile treatment as ``core/digital.mx_attention``, so the
    digital-SDPA semantics do not depend on which attention path the
    sequence length selects. Note the quantization *granularity* differs
    from the dense path (per KV tile vs whole key axis), so dense and
    flash are statistically — not bitwise — equivalent, mirroring the
    tiled systolic hardware."""
    if mx_digital:
        qq, kq = _mx_qk(q, k)
        q, k = qq.astype(q.dtype), kq.astype(k.dtype)
    b, sq, hkv, g, dh = q.shape
    sk = k.shape[1]
    kc = min(ctx.attn_chunk, sk)
    qc = min(ctx.q_chunk, sq)
    nkc = -(-sk // kc)
    nqc = -(-sq // qc)
    pad_k = nkc * kc - sk
    pad_q = nqc * qc - sq
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=KV_PAD)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    kcs = k.reshape(b, nkc, kc, hkv, dh)
    vcs = v.reshape(b, nkc, kc, hkv, dh)
    kps = k_pos.reshape(b, nkc, kc)

    def one_q_chunk(args):
        qi, qpi = args  # [B, qc, Hkv, G, Dh], [B, qc]

        def step(carry, xs):
            m_run, den, acc = carry
            kci, vci, kpi = xs  # [B, kc, ...]
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", qi, kci,
                preferred_element_type=jnp.float32,
            ) * cfg.scale
            if mx_digital:
                s = _mx_score_round(s)
            msk = _mask(qpi, kpi, cfg.causal, cfg.window)  # [B, qc, kc]
            s = jnp.where(msk[:, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(
                jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0
            )
            if mx_digital:  # per-tile P/V re-quant; den accumulates the
                # quantized-P sums, so the final division normalizes
                p, vq, _ = _mx_pv(p, vci)
                vci = vq.astype(vci.dtype)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(vci.dtype), vci)
            acc = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            den = den * corr + jnp.sum(p, axis=-1)
            return (m_new, den, acc), None

        init = (
            jnp.full((b, qc, hkv, g), -jnp.inf, jnp.float32),
            jnp.zeros((b, qc, hkv, g), jnp.float32),
            jnp.zeros((b, qc, hkv, g, dh), jnp.float32),
        )
        (m_run, den, acc), _ = jax.lax.scan(
            step, init, (kcs.swapaxes(0, 1), vcs.swapaxes(0, 1), kps.swapaxes(0, 1))
        )
        den = jnp.where(den == 0.0, 1.0, den)
        return (acc / den[..., None]).astype(q.dtype)

    qcs = q.reshape(b, nqc, qc, hkv, g, dh).swapaxes(0, 1)
    qps = q_pos.reshape(b, nqc, qc).swapaxes(0, 1)
    out = jax.lax.map(one_q_chunk, (qcs, qps))  # [nqc, B, qc, Hkv, G, Dh]
    out = out.swapaxes(0, 1).reshape(b, nqc * qc, hkv, g, dh)
    return out[:, :sq]


def _qkv(ctx: RunCtx, cfg: AttnStatic, p: dict, x: jax.Array, positions,
         rope_tables=None):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = linear_apply(ctx, p["wq"], x, name="wq").reshape(b, s, h, hd)
    k = linear_apply(ctx, p["wk"], x, name="wk").reshape(b, s, kv, hd)
    v = linear_apply(ctx, p["wv"], x, name="wv").reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["qn"], q)
        k = rmsnorm_apply(p["kn"], k)
    if cfg.use_rope:
        if cfg.mrope:
            mp = ropelib.text_mrope_positions(positions)
            sec = (hd // 8, hd * 3 // 16, hd * 3 // 16)
            q = ropelib.apply_mrope(q, mp, cfg.rope_theta, sec)
            k = ropelib.apply_mrope(k, mp, cfg.rope_theta, sec)
        else:
            q = ropelib.apply_rope(q, positions, cfg.rope_theta,
                                   tables=rope_tables)
            k = ropelib.apply_rope(k, positions, cfg.rope_theta,
                                   tables=rope_tables)
    return q, k, v


def attn_apply(
    ctx: RunCtx,
    cfg: AttnStatic,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None = None,
    pos: jax.Array | None = None,
    rope_tables=None,
):
    """Pre-norm attention sublayer with residual.

    Train/prefill: ``cache=None``, positions [B, S].
    Decode: ``cache={'k','v'}`` ring/linear buffers, ``pos`` scalar int32
    (current length; the new token is written at slot pos % W).
    Chunked prefill: ``cache`` + S > 1 + ``pos`` (chunk start offset) —
    ``positions`` carry absolute prompt offsets and fresh rows land at
    their absolute page slots (serving engine ``chunk_len`` path).
    ``rope_tables`` shares precomputed RoPE cos/sin across layers.
    Returns (y, new_cache).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    g = h // kv
    mx_dig = ctx.hybrid_digital_sdpa
    xn = norm_apply(cfg.norm, p["ln"], x)
    q, k, v = _qkv(ctx, cfg, p, xn, positions, rope_tables=rope_tables)
    if s > 1:
        # zero K/V at KV_PAD positions (fixed-shape padded serving
        # prefill). The mask already excludes them from scores, but the
        # digital-MXFP4 SDPA quantizes V in shared-exponent blocks along
        # the key axis — garbage pad rows would perturb real rows' codes,
        # and they would land in the decode cache.
        kvm = (positions > _KV_PAD_MIN)[:, :, None, None]
        k = jnp.where(kvm, k, jnp.zeros((), k.dtype))
        v = jnp.where(kvm, v, jnp.zeros((), v.dtype))
    q = ctx.act(q.reshape(b, s, kv, g, hd), "batch", "seq", "kv_heads", "heads_g", "head_dim")

    fused = cache is not None and "kv" in cache
    if cache is not None and s > 1 and pos is not None:
        # chunked prefill into an existing page: the engine feeds one
        # fixed-shape [B, chunk] window of a longer prompt per step, with
        # ``positions`` carrying absolute prompt offsets (KV_PAD on pad
        # columns) and ``pos`` the chunk's start offset. Fresh K/V rows
        # are written at their absolute slots — pad columns map out of
        # bounds and are dropped — and this chunk's queries attend over
        # the *whole* page: rows beyond the written prefix are zero
        # (pages are reset/cloned at admission, see kvcache.clone_prefix)
        # and carry k_pos > q_pos, so the causal mask excludes them.
        w = (cache["kv"] if fused else cache["k"]).shape[1]
        rows = jnp.arange(b)[:, None]
        slot = jnp.where(positions > _KV_PAD_MIN, positions, w)
        if fused:
            dt = cache["kv"].dtype
            kvnew = paged_layout.fuse_kv(k.astype(dt), v.astype(dt))
            ckv = cache["kv"].at[rows, slot].set(kvnew, mode="drop")
            new_cache = {"kv": ckv}
            kpage, vpage = paged_layout.split_kv(ckv)
            if "kv_codes" in cache:
                new_cache.update(paged_layout.quant_page_full(kpage, vpage))
        else:
            ck = cache["k"].at[rows, slot].set(
                k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[rows, slot].set(
                v.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": ck, "v": cv}
            kpage, vpage = ck, cv
            if "k_codes" in cache:
                # the quantized-resident mirrors are recomputed for the
                # FULL page every chunk (O(page) per chunk, documented):
                # blockwise V exponents near the chunk boundary depend on
                # rows outside the chunk, and mirrors == full requant of
                # the raw page is the invariant that makes pages
                # content-addressable (serving/prefix.py). Nothing reads
                # the pre-chunk mirror state here, so a cloned page only
                # needs its raw rows copied.
                new_cache.update(_quant_cache_full(ck, cv))
        k_pos = jnp.broadcast_to(jnp.arange(w)[None], (b, w))
        o = _dense_attn(q, kpage, vpage, positions, k_pos, cfg,
                        mx_digital=mx_dig)
    elif cache is not None and s > 1:
        # prefill-into-cache: attention over the fresh K/V, cache filled
        # with the last W positions (ring convention: slot = pos % W)
        w = (cache["kv"] if fused else cache["k"]).shape[1]
        if s < w:
            kw = jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            vw = jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        else:
            kw, vw = k[:, -w:], v[:, -w:]
            roll = s % w
            if roll:
                kw = jnp.roll(kw, roll, axis=1)
                vw = jnp.roll(vw, roll, axis=1)
        if fused:
            dt = cache["kv"].dtype
            kcast, vcast = kw.astype(dt), vw.astype(dt)
            new_cache = {"kv": paged_layout.fuse_kv(kcast, vcast)}
            if "kv_codes" in cache:
                # same quantize calls as the legacy mirror fill, repacked
                new_cache.update(paged_layout.quant_page_full(kcast, vcast))
        else:
            new_cache = {"k": kw.astype(cache["k"].dtype),
                         "v": vw.astype(cache["v"].dtype)}
            if "k_codes" in cache:
                # quantized-resident pool: fill the code mirrors from the
                # cache-dtype-cast pages (what requant-per-step would see)
                new_cache.update(
                    _quant_cache_full(new_cache["k"], new_cache["v"])
                )
        k = ctx.act(k, "batch", "kv_seq", "kv_heads", "head_dim")
        v = ctx.act(v, "batch", "kv_seq", "kv_heads", "head_dim")
        if s <= ctx.dense_attn_max:
            o = _dense_attn(q, k, v, positions, positions, cfg,
                            mx_digital=mx_dig)
        else:
            o = _flash_attn(q, k, v, positions, positions, cfg, ctx,
                            mx_digital=mx_dig)
    elif fused:
        # fused paged decode: one ragged flash-decode call over the
        # head-interleaved page pool. ``ctx.paged_rows`` maps lanes to
        # pool rows (continuous-batching serving decodes in place — no
        # per-step gather/scatter of full pages); without it lane i reads
        # row i, which is exactly the legacy per-lane cache convention.
        w = cache["kv"].shape[1]
        pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))
        rows = (jnp.arange(b) if ctx.paged_rows is None
                else ctx.paged_rows)
        slot = pos_b % w
        kvnew = paged_layout.fuse_kv(k[:, 0], v[:, 0])
        ckv = cache["kv"].at[rows, slot].set(
            kvnew.astype(cache["kv"].dtype)
        )
        new_cache = {"kv": ckv}
        resident = "kv_codes" in cache
        if resident:
            new_cache.update(
                paged_layout.quant_page_step(cache, ckv, rows, slot)
            )
        # min(pos+1, W) reproduces the legacy ring-write validity mask
        # ((idx <= pos) | (pos >= w)): a contiguous valid prefix, all W
        # slots once the ring has wrapped
        lengths = jnp.minimum(pos_b + 1, w)
        qd, kv_pages, quant = q, ckv, None
        if mx_dig:
            if not resident:
                raise ValueError(
                    "fused paged decode under a digital-SDPA backend "
                    "needs the quantized-resident mirrors — init the "
                    "cache with mx_digital=True"
                )
            qd = _mx_fq(q).astype(jnp.bfloat16)
            kv_pages = None
            quant = {name: new_cache[name]
                     for name in ("kv_codes", "k_exps", "v_exps")}
        o = paged_ops.ragged_paged_decode(
            qd[:, 0], rows, lengths, kv=kv_pages, quant=quant,
            scale=cfg.scale, use_pallas=ctx.use_pallas,
            interpret=ctx.interpret,
            buffers=ctx.paged_buffers or None,
            obs=ctx.obs,
        )[:, None]
    elif cache is not None:
        # pos may be a scalar (all lanes at the same position) or a [B]
        # vector (continuous-batching serving: each lane decodes at its own
        # position); both write slot pos % w per lane and mask per lane.
        w = cache["k"].shape[1]
        pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))
        slot = pos_b % w
        lanes = jnp.arange(b)
        ck = cache["k"].at[lanes, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[lanes, slot].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        resident = "k_codes" in cache
        if resident:
            new_cache.update(_quant_cache_step(cache, ck, cv, lanes, slot))
        idx = jnp.arange(w)
        valid = (idx[None, :] <= pos_b[:, None]) | (pos_b[:, None] >= w)
        qd, kd = q, ck
        if mx_dig:  # digital MXFP4 systolic SDPA for the hybrid backend
            qd = _mx_fq(q)
            if resident:  # O(1) per-step quantization: read K codes back
                kd = mxlib.dequantize(
                    mxlib.MX(new_cache["k_codes"], new_cache["k_exps"]),
                    out_len=hd,
                )
            else:  # requant-per-step reference: O(cache_len) quantize
                kd = _mx_fq(ck)
            # exact bf16 carriage of the quantized operands (see
            # _dense_attn)
            qd, kd = qd.astype(jnp.bfloat16), kd.astype(jnp.bfloat16)
        sc = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qd, kd, preferred_element_type=jnp.float32
        ) * cfg.scale
        if mx_dig:
            sc = _mx_score_round(sc)
        sc = jnp.where(valid[:, None, None, None, :], sc, -jnp.inf)
        if mx_dig:
            pr, den = _mx_p(jax.nn.softmax(sc, axis=-1))
            if resident:
                vd = jnp.moveaxis(
                    mxlib.dequantize(
                        mxlib.MX(new_cache["v_codes"], new_cache["v_exps"]),
                        out_len=w,
                    ),
                    -1, 1,
                )
            else:
                vd = mxlib.fake_quant_axis(cv, 1)  # bf16-native chain
            o = jnp.einsum(
                "bhgqk,bkhd->bqhgd", pr.astype(jnp.bfloat16),
                vd.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
            )
            o = (o / jnp.moveaxis(den, -2, 1)).astype(cv.dtype)
        else:
            pr = jax.nn.softmax(sc, axis=-1).astype(cv.dtype)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, cv)
    else:
        new_cache = None
        k = ctx.act(k, "batch", "kv_seq", "kv_heads", "head_dim")
        v = ctx.act(v, "batch", "kv_seq", "kv_heads", "head_dim")
        if s <= ctx.dense_attn_max:
            o = _dense_attn(q, k, v, positions, positions, cfg,
                            mx_digital=mx_dig)
        else:
            o = _flash_attn(q, k, v, positions, positions, cfg, ctx,
                            mx_digital=mx_dig)

    o = o.reshape(b, s, h * hd)
    y = linear_apply(ctx, p["wo"], o, name="wo")
    y = ctx.act(y, "batch", "seq", "embed")
    return x + y.astype(x.dtype), new_cache


def attn_cache_init(cfg: AttnStatic, batch: int, max_len: int,
                    dtype=jnp.bfloat16, mx_digital: bool = False,
                    fused: bool = False):
    """K/V decode cache; with ``mx_digital`` it additionally carries the
    quantized-resident code mirrors for the digital-SDPA decode path.
    ``fused`` selects the head-interleaved paged layout served by the
    ragged paged flash-decode kernel (see ``kernels.paged_attention``)."""
    w = min(cfg.window, max_len) if cfg.window > 0 else max_len
    if fused:
        cache = paged_layout.fused_cache_init(
            batch, w, cfg.n_kv, cfg.head_dim, dtype
        )
        if mx_digital:
            cache.update(
                paged_layout.fused_quant_init(batch, w, cfg.n_kv,
                                              cfg.head_dim)
            )
        return cache
    shape = (batch, w, cfg.n_kv, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if mx_digital:
        cache.update(quant_cache_init(batch, w, cfg.n_kv, cfg.head_dim))
    return cache


ATTN_CACHE_SPECS = {
    "k": ("batch", "cache_seq", None, None),
    "v": ("batch", "cache_seq", None, None),
}

ATTN_QUANT_CACHE_SPECS = {
    **ATTN_CACHE_SPECS,
    "k_codes": ("batch", "cache_seq", None, None),
    "k_exps": ("batch", "cache_seq", None, None),
    "v_codes": ("batch", None, None, "cache_seq"),
    "v_exps": ("batch", None, None, None),
}

FUSED_ATTN_CACHE_SPECS = {
    "kv": ("batch", "cache_seq", None, None),
}

FUSED_ATTN_QUANT_CACHE_SPECS = {
    **FUSED_ATTN_CACHE_SPECS,
    "kv_codes": ("batch", "cache_seq", None, None),
    "k_exps": ("batch", "cache_seq", None, None),
    "v_exps": ("batch", None, None, None),  # slot-block-major key axis
}


def attn_cache_specs(mx_digital: bool = False,
                     fused: bool = False) -> dict:
    if fused:
        return (FUSED_ATTN_QUANT_CACHE_SPECS if mx_digital
                else FUSED_ATTN_CACHE_SPECS)
    return ATTN_QUANT_CACHE_SPECS if mx_digital else ATTN_CACHE_SPECS
