"""Mamba2 (SSD) mixer: chunked parallel scan for train/prefill and a
single-step state update for decode. Static projection weights take the
paper's MXFP4 path; the recurrence itself is the "dynamic" compute
(digital-path analogue — see DESIGN.md §Arch-applicability)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.layers.common import RunCtx, linear_apply, linear_init, norm_apply, norm_init


@dataclasses.dataclass(frozen=True)
class MambaStatic:
    d_model: int
    n_heads: int
    head_dim: int  # P
    d_state: int  # N
    n_groups: int = 1
    conv_k: int = 4
    chunk: int = 256
    norm: str = "rmsnorm"

    @property
    def d_inner(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_init(key, cfg: MambaStatic):
    ks = jax.random.split(key, 4)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    gn = cfg.n_groups * cfg.d_state
    p, s = {}, {}
    p["ln"], s["ln"] = norm_init(cfg.norm, d)
    p["in_proj"], s["in_proj"] = linear_init(
        ks[0], d, 2 * di + 2 * gn + h, out_axis="mlp"
    )
    p["conv_w"] = (
        jax.random.normal(ks[1], (cfg.conv_dim, cfg.conv_k), jnp.float32)
        * (1.0 / cfg.conv_k) ** 0.5
    )
    s["conv_w"] = ("mlp", "conv")
    p["conv_b"] = jnp.zeros((cfg.conv_dim,), jnp.float32)
    s["conv_b"] = ("mlp",)
    p["A_log"] = jnp.log(
        jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
    )  # A = -exp(A_log)
    s["A_log"] = ("heads",)
    p["D"] = jnp.ones((h,), jnp.float32)
    s["D"] = ("heads",)
    p["dt_bias"] = jnp.zeros((h,), jnp.float32)
    s["dt_bias"] = ("heads",)
    p["gn"], s["gn"] = norm_init("rmsnorm", di)
    p["out_proj"], s["out_proj"] = linear_init(
        ks[2], di, d, in_axis="mlp", out_axis="embed"
    )
    return p, s


def _split_zxbcdt(cfg: MambaStatic, zxbcdt: jax.Array):
    di, gn, h = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _conv1d(cfg: MambaStatic, p, xbc: jax.Array) -> jax.Array:
    """Causal depthwise conv over [B, S, C]."""
    k = cfg.conv_k
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * p["conv_w"][:, i] for i in range(k)
    )
    return jax.nn.silu(out + p["conv_b"].astype(out.dtype))


def _ssd_chunked(x, dt, a, bm, cm, chunk: int):
    """x [B,S,H,P], dt [B,S,H], a [H] (<0), bm/cm [B,S,G,N].
    Returns y [B,S,H,P] and the final state [B,H,P,N]."""
    b, s, h, pp = x.shape
    n = bm.shape[-1]
    g = bm.shape[-2]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    c = sp // q
    rep = h // g
    xc = x.reshape(b, c, q, h, pp).astype(jnp.float32)
    dtc = dt.reshape(b, c, q, h).astype(jnp.float32)
    bc = jnp.repeat(bm.reshape(b, c, q, g, n), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(cm.reshape(b, c, q, g, n), rep, axis=3).astype(jnp.float32)

    dta = dtc * a  # [b,c,q,h] (<= 0)
    csh = jnp.cumsum(dta, axis=2).transpose(0, 1, 3, 2)  # [b,c,h,q]
    xd = xc * dtc[..., None]

    # intra-chunk (attention-like with decay mask)
    diff = csh[..., :, None] - csh[..., None, :]  # [b,c,h,i,j]
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: the i<j half has diff>0 and would overflow to inf,
    # poisoning the VJP with 0*inf even though the value is masked out.
    ll = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    cb = jnp.einsum("bcihn,bcjhn->bchij", cc, bc)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", cb * ll, xd)

    # chunk states
    decay_end = jnp.exp(csh[..., -1:] - csh)  # [b,c,h,q]
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn", bc, decay_end, xd)
    chunk_decay = jnp.exp(csh[..., -1])  # [b,c,h]

    def step(s_prev, inp):
        cd, st = inp
        return s_prev * cd[..., None, None] + st, s_prev

    s0 = jnp.zeros((b, h, pp, n), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step, s0, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1))
    )
    s_prevs = s_prevs.swapaxes(0, 1)  # [b,c,h,p,n] state entering chunk

    decay_in = jnp.exp(csh)  # [b,c,h,q]
    y_inter = jnp.einsum("bcqhn,bchq,bchpn->bcqhp", cc, decay_in, s_prevs)
    y = (y_intra + y_inter).reshape(b, sp, h, pp)[:, :s]
    return y, s_final


def mamba_apply(
    ctx: RunCtx,
    cfg: MambaStatic,
    p: dict,
    x: jax.Array,
    cache: dict | None = None,
):
    """Pre-norm Mamba2 sublayer with residual. Returns (y, new_cache)."""
    b, s, d = x.shape
    h, pp, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    xn = norm_apply(cfg.norm, p["ln"], x)
    zxbcdt = linear_apply(ctx, p["in_proj"], xn)
    z, xbc, dt = _split_zxbcdt(cfg, zxbcdt)
    a = -jnp.exp(p["A_log"])  # [h]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    if cache is None or s > 1:
        xbc_raw = xbc
        xbc = _conv1d(cfg, p, xbc)
        xin = xbc[..., : cfg.d_inner].reshape(b, s, h, pp)
        xin = ctx.act(xin, "batch", "seq", "heads", "head_dim")
        bm = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(b, s, g, n)
        cm = xbc[..., cfg.d_inner + g * n :].reshape(b, s, g, n)
        y, s_final = _ssd_chunked(xin, dtv, a, bm, cm, cfg.chunk)
        new_cache = None
        if cache is not None:  # prefill-into-cache handoff
            kk = cfg.conv_k - 1
            tail = xbc_raw[:, -kk:].astype(jnp.float32)
            if s < kk:
                tail = jnp.pad(tail, ((0, 0), (kk - s, 0), (0, 0)))
            new_cache = {"conv": tail.swapaxes(1, 2), "state": s_final}
    else:
        # single-step decode: x [b, 1, d]
        win = jnp.concatenate(
            [cache["conv"], xbc.astype(jnp.float32).swapaxes(1, 2)], axis=-1
        )  # [b, convdim, k]
        conv_out = jax.nn.silu(
            jnp.sum(win * p["conv_w"][None], axis=-1) + p["conv_b"]
        )  # [b, convdim]
        new_conv = win[..., 1:]
        xin = conv_out[:, : cfg.d_inner].reshape(b, h, pp)
        bm = conv_out[:, cfg.d_inner : cfg.d_inner + g * n].reshape(b, g, n)
        cm = conv_out[:, cfg.d_inner + g * n :].reshape(b, g, n)
        bh = jnp.repeat(bm, h // g, axis=1)
        ch = jnp.repeat(cm, h // g, axis=1)
        dt1 = dtv[:, 0]  # [b, h]
        da = jnp.exp(dt1 * a)  # [b, h]
        xd = xin * dt1[..., None]
        st = cache["state"] * da[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xd, bh
        )
        y = jnp.einsum("bhn,bhpn->bhp", ch, st)[:, None]  # [b,1,h,p]
        new_cache = {"conv": new_conv, "state": st}
        s_final = st

    y = y + xin.reshape(y.shape) * p["D"][:, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = norm_apply("rmsnorm", p["gn"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = linear_apply(ctx, p["out_proj"], y.astype(jnp.bfloat16))
    out = ctx.act(out, "batch", "seq", "embed")
    return x + out.astype(x.dtype), new_cache


def mamba_cache_init(cfg: MambaStatic, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim, cfg.conv_k - 1), jnp.float32),
        "state": jnp.zeros(
            (batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32
        ),
    }


MAMBA_CACHE_SPECS = {
    "conv": ("batch", "mlp", "conv"),
    "state": ("batch", "state_heads", "head_dim", "state"),
}
