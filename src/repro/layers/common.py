"""Shared layer utilities: sharding context, quantized linear, norms.

Parameters are plain nested dicts of ``jax.Array``; every ``*_init``
returns ``(params, specs)`` where ``specs`` mirrors the params tree with
tuples of *logical* axis names (resolved to mesh axes by
``repro.distributed.sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import mx as mxlib


# --------------------------------------------------------------- sharding

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "kv_seq": None,  # attention K/V sequence axis (SP when heads unshardable)
    "cache_seq": None,  # resident KV-cache sequence axis (flash-decoding)
    "state_heads": None,  # SSM/xLSTM state head axis
    "qkv_fused": None,
    "kv_fused": None,
    "heads_g": None,
    "exp_group": ("pod", "data"),  # grouped MoE dispatch (per DP shard)
    "exp_e": None,  # replicated expert axis around dispatch/combine
    "exp_cap": None,
    "conv": None,
    "state": None,
    "zero": None,
    "layers": None,
    "replicated": None,
}


@dataclasses.dataclass
class ShardingCtx:
    """Resolves logical axis names to mesh axes and applies activation
    sharding constraints. With ``mesh=None`` everything is a no-op (single
    device smoke tests)."""

    mesh: Any = None
    rules: dict[str, Any] = dataclasses.field(default_factory=dict)

    def resolve(self, logical_axes) -> P:
        names = []
        used = set()
        for ax in logical_axes:
            r = self.rules.get(ax, DEFAULT_RULES.get(ax)) if ax else None
            if isinstance(r, (list, tuple)):
                r = tuple(a for a in r if self.mesh and a in self.mesh.axis_names)
                r = tuple(a for a in r if a not in used) or None
            elif r is not None:
                if self.mesh is not None and r not in self.mesh.axis_names:
                    r = None
                if r in used:
                    r = None
            if r is not None:
                used.update(r if isinstance(r, tuple) else (r,))
            names.append(r)
        return P(*names)

    def act(self, x: jax.Array, *logical_axes) -> jax.Array:
        """Apply a sharding constraint to an activation."""
        if self.mesh is None:
            return x
        assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
        spec = self.resolve(logical_axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Per-call context threaded through model apply functions."""

    shd: ShardingCtx
    quant: str = "none"  # none | mxfp4_ste | mxfp4_wonly | cim
    impl: str = "jnp"  # jnp | pallas
    decode: bool = False
    attn_chunk: int = 1024  # KV chunk for the online-softmax path
    q_chunk: int = 2048
    dense_attn_max: int = 2048  # below this seq len use the dense path
    unroll_scans: bool = False  # blockwise cost analysis: count loop trips

    def act(self, x, *axes):
        return self.shd.act(x, *axes)


# ----------------------------------------------------------------- linear

def linear_init(
    key,
    k: int,
    n: int,
    *,
    use_bias: bool = False,
    in_axis: str = "embed",
    out_axis: str = "mlp",
    scale: float | None = None,
):
    scale = (1.0 / k) ** 0.5 if scale is None else scale
    w = jax.random.normal(key, (k, n), jnp.float32) * scale
    params = {"w": w}
    specs = {"w": (in_axis, out_axis)}
    if use_bias:
        params["b"] = jnp.zeros((n,), jnp.float32)
        specs["b"] = (out_axis,)
    return params, specs


def linear_apply(ctx: RunCtx, params: dict, x: jax.Array) -> jax.Array:
    """Quantization-mode-dispatched linear. x: [..., K] (bf16)."""
    if "codes" in params:  # serving-converted MXFP4 weight-only params
        if ctx.impl == "pallas":
            from repro.kernels.mxfp4_matmul import ops as mmops

            y = mmops.mxfp4_matmul(
                x, params["codes"], params["exps"], interpret=True
            )
        else:
            w = _dequant_packed(params["codes"], params["exps"])
            y = jnp.matmul(x.astype(jnp.bfloat16), w)
    else:
        w = params["w"].astype(jnp.bfloat16)
        if ctx.quant == "mxfp4_ste":
            wq = mxlib.fake_quant_axis(params["w"], axis=0)
            xq = mxlib.fake_quant(x.astype(jnp.float32))
            y = jnp.matmul(
                xq.astype(jnp.bfloat16), wq.astype(jnp.bfloat16)
            )
        elif ctx.quant == "mxfp4_ste_prequant":
            # weights were fake-quantized once at the step boundary
            # (exact: weights are constant within a step) — gathers move
            # bf16 instead of f32 and the quant ops run once, not k_micro
            # times
            xq = mxlib.fake_quant(x.astype(jnp.float32))
            y = jnp.matmul(xq.astype(jnp.bfloat16), w)
        else:
            y = jnp.matmul(x.astype(jnp.bfloat16), w)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def _dequant_packed(codes: jax.Array, exps: jax.Array) -> jax.Array:
    """packed uint8 codes [K//2, N] + biased exps [K//32, N] -> bf16 [K, N].

    All-bf16 arithmetic: codes/2 and 2^e are exactly representable in
    bf16, so this is bit-identical to the f32 path while cutting the
    dequant intermediate traffic ~3x (decode is weight-read bound —
    EXPERIMENTS.md §Perf; the Pallas kernel removes even this by
    expanding inside VMEM)."""
    kp2, n = codes.shape[-2], codes.shape[-1]
    k = kp2 * 2
    c = jnp.swapaxes(mxlib.unpack_codes(jnp.swapaxes(codes, -1, -2)), -1, -2)
    scale = mxlib.exp2i(mxlib.exps_from_biased(exps) - 1).astype(
        jnp.bfloat16
    )  # 2^(e-1) == 0.5 * 2^e, exact
    cb = c.reshape(c.shape[:-2] + (k // 32, 32, n)).astype(jnp.bfloat16)
    w = cb * scale[..., :, None, :]
    return w.reshape(c.shape[:-2] + (k, n))


def _quantize_packed(w: jax.Array) -> dict:
    """[..., K, N] float -> packed MXFP4 {codes [..., K//2, N] uint8,
    exps [..., K//32, N] uint8} quantized along K."""
    mxq = mxlib.quantize(jnp.swapaxes(w, -1, -2))
    codes = jnp.swapaxes(mxq.codes, -1, -2)
    packed = jnp.swapaxes(
        mxlib.pack_codes(jnp.swapaxes(codes, -1, -2)), -1, -2
    )
    exps = mxlib.exps_to_biased(jnp.swapaxes(mxq.exps, -1, -2))
    return {"codes": packed, "exps": exps}


def quantize_linear_params(params: dict) -> dict:
    """Convert a float linear param dict to packed MXFP4 (weight-only)."""
    out = _quantize_packed(params["w"])
    if "b" in params:
        out["b"] = params["b"]
    return out


def is_linear_params(p) -> bool:
    return isinstance(p, dict) and "w" in p and getattr(p["w"], "ndim", 0) == 2


def quantize_weights_tree(tree):
    """Step-boundary weight fake-quant for training ("prequant"): exact
    hoisting of the per-linear fake-quant out of the microbatch loop
    (weights are constant within a step), which also makes every FSDP
    all-gather move bf16 instead of f32 and runs the quant ops once
    instead of k_micro times per step."""

    def rec(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if (
                    k == "w"
                    and getattr(v, "ndim", 0) in (2, 3)  # incl. layer-stacked
                    and v.shape[-2] % 32 == 0
                ):
                    out[k] = mxlib.fake_quant_axis(v, -2).astype(jnp.bfloat16)
                elif (
                    k in ("w1", "w2", "w3")
                    and getattr(v, "ndim", 0) in (3, 4)  # incl. layer-stacked
                    and v.shape[-2] % 32 == 0
                ):
                    out[k] = mxlib.fake_quant_axis(v, -2).astype(jnp.bfloat16)
                else:
                    out[k] = rec(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        if hasattr(node, "dtype") and node.dtype == jnp.float32 and node.ndim >= 2:
            return node.astype(jnp.bfloat16)
        return node

    return rec(tree)


def convert_params_mxfp4(tree, min_n: int = 256):
    """Serving transform: every static linear weight with a 32-aligned
    contraction dim and a wide-enough output dim becomes packed MXFP4
    (4.25 b/param resident, the FWS analogue); remaining float params are
    cast to bf16. Pure jnp — usable under jax.eval_shape for dry-runs."""

    def rec(node):
        if isinstance(node, dict):
            out = {}
            if (
                "w" in node
                and getattr(node["w"], "ndim", 0) in (2, 3)
                and node["w"].shape[-2] % 32 == 0
                and node["w"].shape[-1] >= min_n
            ):
                out.update(quantize_linear_params(node))
                for k, v in node.items():
                    if k not in ("w", "b"):
                        out[k] = rec(v)
                return out
            for k, v in node.items():
                if (
                    k in ("w1", "w2", "w3")
                    and getattr(v, "ndim", 0) in (3, 4)
                    and v.shape[-2] % 32 == 0
                ):
                    out[k] = _quantize_packed(v)
                else:
                    out[k] = rec(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        if hasattr(node, "dtype") and node.dtype == jnp.float32:
            return node.astype(jnp.bfloat16)
        return node

    return rec(tree)


def convert_specs_mxfp4(specs, params_struct, min_n: int = 256):
    """Mirror of convert_params_mxfp4 on the logical-axis spec tree.
    params_struct is the *pre-conversion* shape tree (for the gates)."""

    def rec(spec_node, p_node):
        if isinstance(spec_node, dict):
            out = {}
            if (
                "w" in spec_node
                and getattr(p_node.get("w"), "ndim", 0) in (2, 3)
                and p_node["w"].shape[-2] % 32 == 0
                and p_node["w"].shape[-1] >= min_n
            ):
                out["codes"] = spec_node["w"]
                out["exps"] = spec_node["w"]
                for k, v in spec_node.items():
                    if k == "w":
                        continue
                    out[k] = v if k == "b" else rec(v, p_node[k])
                return out
            for k, v in spec_node.items():
                if (
                    k in ("w1", "w2", "w3")
                    and getattr(p_node.get(k), "ndim", 0) in (3, 4)
                    and p_node[k].shape[-2] % 32 == 0
                ):
                    out[k] = {"codes": v, "exps": v}
                else:
                    out[k] = rec(v, p_node[k])
            return out
        if isinstance(spec_node, (list, tuple)) and not _spec_leaf(spec_node):
            return type(spec_node)(
                rec(v, p) for v, p in zip(spec_node, p_node)
            )
        return spec_node

    return rec(specs, params_struct)


def _spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )


# ------------------------------------------------------------------ norms

def rmsnorm_init(d: int):
    return {"gamma": jnp.ones((d,), jnp.float32)}, {"gamma": ("embed",)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["gamma"]
    return y.astype(x.dtype)


def layernorm_init(d: int):
    return (
        {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)},
        {"gamma": ("embed",), "beta": ("embed",)},
    )


def layernorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["gamma"] + params["beta"]
    return y.astype(x.dtype)


def norm_init(kind: str, d: int):
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    return rmsnorm_apply(params, x) if kind == "rmsnorm" else layernorm_apply(params, x)


# ------------------------------------------------------------- embeddings

def embed_init(key, vocab: int, d: int):
    emb = jax.random.normal(key, (vocab, d), jnp.float32) * (d**-0.5)
    return {"emb": emb}, {"emb": ("vocab", "embed")}


def embed_apply(ctx: RunCtx, params: dict, ids: jax.Array) -> jax.Array:
    out = jnp.take(params["emb"].astype(jnp.bfloat16), ids, axis=0)
    return out
