"""Shared layer utilities: sharding context, quantized linear, norms.

Parameters are plain nested dicts of ``jax.Array``; every ``*_init``
returns ``(params, specs)`` where ``specs`` mirrors the params tree with
tuples of *logical* axis names (resolved to mesh axes by
``repro.distributed.sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import kernels as kernels_lib
from repro.core import mx as mxlib
from repro.layers import backends as backends_lib
from repro.layers.backends import (  # noqa: F401  (re-exported API)
    ActivationTap,
    _dequant_packed,
    _quantize_packed,
    backend_names,
    calibrate_taps,
    convert_params_cim,
    get_backend,
    quantize_linear_params,
    register_backend,
    resolve_backend,
)


# --------------------------------------------------------------- sharding

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "kv_seq": None,  # attention K/V sequence axis (SP when heads unshardable)
    "cache_seq": None,  # resident KV-cache sequence axis (flash-decoding)
    "state_heads": None,  # SSM/xLSTM state head axis
    "qkv_fused": None,
    "kv_fused": None,
    "heads_g": None,
    "exp_group": ("pod", "data"),  # grouped MoE dispatch (per DP shard)
    "exp_e": None,  # replicated expert axis around dispatch/combine
    "exp_cap": None,
    "conv": None,
    "state": None,
    "zero": None,
    "layers": None,
    "replicated": None,
}


@dataclasses.dataclass
class ShardingCtx:
    """Resolves logical axis names to mesh axes and applies activation
    sharding constraints. With ``mesh=None`` everything is a no-op (single
    device smoke tests)."""

    mesh: Any = None
    rules: dict[str, Any] = dataclasses.field(default_factory=dict)

    def resolve(self, logical_axes) -> P:
        names = []
        used = set()
        for ax in logical_axes:
            r = self.rules.get(ax, DEFAULT_RULES.get(ax)) if ax else None
            if isinstance(r, (list, tuple)):
                r = tuple(a for a in r if self.mesh and a in self.mesh.axis_names)
                r = tuple(a for a in r if a not in used) or None
            elif r is not None:
                if self.mesh is not None and r not in self.mesh.axis_names:
                    r = None
                if r in used:
                    r = None
            if r is not None:
                used.update(r if isinstance(r, tuple) else (r,))
            names.append(r)
        return P(*names)

    def act(self, x: jax.Array, *logical_axes) -> jax.Array:
        """Apply a sharding constraint to an activation."""
        if self.mesh is None:
            return x
        assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
        spec = self.resolve(logical_axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Per-call context threaded through model apply functions.

    ``quant`` names a linear-execution backend from
    ``repro.layers.backends`` (aliases: ``none -> float_bf16``,
    ``cim -> cim_analog``); unknown names raise ``ValueError`` at the first
    linear. ``impl`` selects the linear execution engine: ``"auto"`` (the
    default) runs compiled Pallas kernels on real accelerators and the
    pure-jnp reference on CPU (see :meth:`use_pallas`); ``"jnp"`` /
    ``"pallas"`` force one side. ``interpret`` is threaded into every
    ``pallas_call``; its default is platform-derived (True only on CPU,
    where there is no Mosaic lowering) so TPU runs never silently
    interpret.
    """

    shd: ShardingCtx
    quant: str = "none"  # backend name: none|mxfp4_ste|mxfp4_ste_prequant|mxfp4_wonly|cim
    impl: str = "auto"  # auto | jnp | pallas
    interpret: bool = dataclasses.field(
        default_factory=kernels_lib.default_interpret
    )  # Pallas interpret mode (platform default: True only on CPU)
    decode: bool = False
    attn_chunk: int = 1024  # KV chunk for the online-softmax path
    q_chunk: int = 2048
    dense_attn_max: int = 2048  # below this seq len use the dense path
    unroll_scans: bool = False  # blockwise cost analysis: count loop trips
    cim: Any = None  # CIMConfig override for the cim_analog backend
    tap: Any = None  # ActivationTap during eager calibration capture
    scope: str = ""  # param-tree path prefix while a tap is active
    # Unroll scanned layer stacks into a Python loop. XLA fuses the whole
    # scan body into one computation, and 1-ulp fusion differences in
    # log2/div flip MXFP4 codes at rounding boundaries — so cross-graph
    # numerics-identity checks (analog vs digital) are only bitwise under
    # unrolled op-by-op execution. Implied by an active tap.
    unroll_layers: bool = False
    # int32 [batch] pool row per lane for the fused paged-KV decode path
    # (None: lane i reads cache row i). Threaded *inside* the traced step
    # via dataclasses.replace — an array field, so a RunCtx carrying it
    # must never be closed over as a static value.
    paged_rows: Any = None
    paged_buffers: int = 0  # DMA ring depth override for the paged kernel (0: auto)
    # Telemetry handle (repro.obs.Obs), threaded into the kernel ops
    # wrappers: named profiling scopes + dispatch counters, and — only
    # with obs.profile=True — eager wall-clock capture. None keeps the
    # bare named scopes (zero runtime cost). Host-side Python object:
    # only ever closed over, never traced.
    obs: Any = None
    # Numerical-fidelity probe (repro.obs.FidelityProbe) during an eager
    # instrumented run: per-layer MXFP4/ADC health keyed by the same
    # scoped paths as calibration. Host-side Python object — only ever
    # closed over, never traced; implies unrolled layer execution like an
    # active tap. None (the default) leaves the hot path untouched.
    fidelity: Any = None

    def act(self, x, *axes):
        return self.shd.act(x, *axes)

    def scoped(self, name: str) -> "RunCtx":
        """Extend the capture scope. No-op (returns self) unless an
        ActivationTap or FidelityProbe is active, so traced paths never
        pay for it."""
        if self.tap is None and self.fidelity is None:
            return self
        return dataclasses.replace(
            self, scope=f"{self.scope}/{name}" if self.scope else name
        )

    @property
    def use_pallas(self) -> bool:
        """Linear-engine dispatch: ``impl="auto"`` selects compiled Pallas
        on TPU and the jnp reference elsewhere (the kernels are
        Mosaic/TPU kernels — on CPU/GPU they would only run under the
        slow interpreter)."""
        if self.impl == "auto":
            return jax.default_backend() == "tpu"
        return self.impl == "pallas"

    @property
    def hybrid_digital_sdpa(self) -> bool:
        """Under the hybrid analog backend (and the fully-digital MXFP4
        eval mode), SDPA runs on the digital MXFP4 systolic path (paper
        §4.4-4.5); QKV/O stay analog for ``cim``."""
        return self.quant in ("cim", "cim_analog", "mxfp4_digital")


# ----------------------------------------------------------------- linear

def linear_init(
    key,
    k: int,
    n: int,
    *,
    use_bias: bool = False,
    in_axis: str = "embed",
    out_axis: str = "mlp",
    scale: float | None = None,
):
    scale = (1.0 / k) ** 0.5 if scale is None else scale
    w = jax.random.normal(key, (k, n), jnp.float32) * scale
    params = {"w": w}
    specs = {"w": (in_axis, out_axis)}
    if use_bias:
        params["b"] = jnp.zeros((n,), jnp.float32)
        specs["b"] = (out_axis,)
    return params, specs


def linear_apply(
    ctx: RunCtx, params: dict, x: jax.Array, name: str | None = None
) -> jax.Array:
    """Backend-dispatched linear. x: [..., K] (bf16).

    Execution is resolved by ``repro.layers.backends``: converted-param
    markers (packed MXFP4 codes, resident CIM codes + calib) win, otherwise
    ``ctx.quant`` names the backend; unknown names raise ``ValueError``.
    ``name`` is the call-site's local param key ("wq", "w1", ...) — with an
    active ``ActivationTap`` it extends ``ctx.scope`` into the full
    param-tree path used to key Row-Hist calibration.
    """
    if ctx.tap is not None and name is not None:
        path = f"{ctx.scope}/{name}" if ctx.scope else name
        ctx.tap.record(path, params, x)
    if ctx.fidelity is not None and name is not None:
        path = f"{ctx.scope}/{name}" if ctx.scope else name
        ctx.fidelity.observe_linear(path, ctx, params, x)
    y = backends_lib.resolve_backend(ctx, params).forward(ctx, params, x)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def is_linear_params(p) -> bool:
    return isinstance(p, dict) and "w" in p and getattr(p["w"], "ndim", 0) == 2


def quantize_weights_tree(tree):
    """Step-boundary weight fake-quant for training ("prequant"): exact
    hoisting of the per-linear fake-quant out of the microbatch loop
    (weights are constant within a step), which also makes every FSDP
    all-gather move bf16 instead of f32 and runs the quant ops once
    instead of k_micro times per step."""

    def rec(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if (
                    k == "w"
                    and getattr(v, "ndim", 0) in (2, 3)  # incl. layer-stacked
                    and v.shape[-2] % 32 == 0
                ):
                    out[k] = mxlib.fake_quant_axis(v, -2).astype(jnp.bfloat16)
                elif (
                    k in ("w1", "w2", "w3")
                    and getattr(v, "ndim", 0) in (3, 4)  # incl. layer-stacked
                    and v.shape[-2] % 32 == 0
                ):
                    out[k] = mxlib.fake_quant_axis(v, -2).astype(jnp.bfloat16)
                else:
                    out[k] = rec(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        if hasattr(node, "dtype") and node.dtype == jnp.float32 and node.ndim >= 2:
            return node.astype(jnp.bfloat16)
        return node

    return rec(tree)


def convert_params_mxfp4(tree, min_n: int = 256):
    """Serving transform: every static linear weight with a 32-aligned
    contraction dim and a wide-enough output dim becomes packed MXFP4
    (4.25 b/param resident, the FWS analogue); remaining float params are
    cast to bf16. Pure jnp — usable under jax.eval_shape for dry-runs."""

    def rec(node):
        if isinstance(node, dict):
            out = {}
            if (
                "w" in node
                and getattr(node["w"], "ndim", 0) in (2, 3)
                and node["w"].shape[-2] % 32 == 0
                and node["w"].shape[-1] >= min_n
            ):
                out.update(quantize_linear_params(node))
                for k, v in node.items():
                    if k not in ("w", "b"):
                        out[k] = rec(v)
                return out
            for k, v in node.items():
                if (
                    k in ("w1", "w2", "w3")
                    and getattr(v, "ndim", 0) in (3, 4)
                    and v.shape[-2] % 32 == 0
                ):
                    out[k] = _quantize_packed(v)
                else:
                    out[k] = rec(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        if hasattr(node, "dtype") and node.dtype == jnp.float32:
            return node.astype(jnp.bfloat16)
        return node

    return rec(tree)


def convert_specs_mxfp4(specs, params_struct, min_n: int = 256):
    """Mirror of convert_params_mxfp4 on the logical-axis spec tree.
    params_struct is the *pre-conversion* shape tree (for the gates)."""

    def rec(spec_node, p_node):
        if isinstance(spec_node, dict):
            out = {}
            if (
                "w" in spec_node
                and getattr(p_node.get("w"), "ndim", 0) in (2, 3)
                and p_node["w"].shape[-2] % 32 == 0
                and p_node["w"].shape[-1] >= min_n
            ):
                out["codes"] = spec_node["w"]
                out["exps"] = spec_node["w"]
                for k, v in spec_node.items():
                    if k == "w":
                        continue
                    out[k] = v if k == "b" else rec(v, p_node[k])
                return out
            for k, v in spec_node.items():
                if (
                    k in ("w1", "w2", "w3")
                    and getattr(p_node.get(k), "ndim", 0) in (3, 4)
                    and p_node[k].shape[-2] % 32 == 0
                ):
                    out[k] = {"codes": v, "exps": v}
                else:
                    out[k] = rec(v, p_node[k])
            return out
        if isinstance(spec_node, (list, tuple)) and not _spec_leaf(spec_node):
            return type(spec_node)(
                rec(v, p) for v, p in zip(spec_node, p_node)
            )
        return spec_node

    return rec(specs, params_struct)


def _spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )


# ------------------------------------------------------------------ norms

def rmsnorm_init(d: int):
    return {"gamma": jnp.ones((d,), jnp.float32)}, {"gamma": ("embed",)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["gamma"]
    return y.astype(x.dtype)


def layernorm_init(d: int):
    return (
        {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)},
        {"gamma": ("embed",), "beta": ("embed",)},
    )


def layernorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["gamma"] + params["beta"]
    return y.astype(x.dtype)


def norm_init(kind: str, d: int):
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    return rmsnorm_apply(params, x) if kind == "rmsnorm" else layernorm_apply(params, x)


# ------------------------------------------------------------- embeddings

def embed_init(key, vocab: int, d: int):
    emb = jax.random.normal(key, (vocab, d), jnp.float32) * (d**-0.5)
    return {"emb": emb}, {"emb": ("vocab", "embed")}


def embed_apply(ctx: RunCtx, params: dict, ids: jax.Array) -> jax.Array:
    out = jnp.take(params["emb"].astype(jnp.bfloat16), ids, axis=0)
    return out
