"""Render EXPERIMENTS.md from dryrun/*.json + dryrun/perf_log.json."""

import json
import os

HEAD = """# EXPERIMENTS

All dry-runs and rooflines target TPU v5e-class hardware (197 TFLOP/s bf16,
16 GB HBM @ 819 GB/s, ~50 GB/s/link ICI per chip); this container is
CPU-only, so `.lower().compile()` artifacts are the measurement substrate.

Roofline terms come from **per-block compiles** (trip-count exact — XLA's
cost analysis counts a `lax.scan` body once, see
`src/repro/distributed/blockwise.py`); the full-model compile provides the
existence + memory proof below. Collective wire bytes use a ring model
over the post-SPMD HLO collectives. MODEL_FLOPS = 6·N·D (train) or
2·N_active·D (serve).

Skipped cells (per assignment rules, DESIGN.md §4):
{skips}

## §Dry-run — full-model compile, every cell x both meshes

Every (arch x applicable shape) lowered AND compiled on the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh (512 host devices; the
multi-pod pass proves the "pod" axis shards). args/temp = per-device
`memory_analysis()`.

"""


def fmt_table(rows, multi=False):
    out = [
        "| arch | shape | compile s | args GiB/dev | temp GiB/dev | fits 16GB |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR {r['error']} | | | |")
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} "
            f"| {m['argument_bytes']/2**30:.2f} | {m['temp_bytes']/2**30:.2f} "
            f"| {'yes' if m['fits_16GB'] else 'NO'} |"
        )
    return "\n".join(out)


def fmt_roofline(rows):
    out = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | dominant "
        "| roofline frac | MODEL/HLO flops | k_micro |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "error" in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['roofline_fraction']:.3f} "
            f"| {r['useful_flops_ratio']:.2f} | {r.get('k_micro', 1)} |"
        )
    return "\n".join(out)


def main():
    single = json.load(open("dryrun/single_pod.json"))
    multi = json.load(open("dryrun/multi_pod.json"))
    from repro import configs as C

    skips = "\n".join(
        f"  - {a} x {s}: {why}" for a, s, why in C.skipped_cells()
    )
    parts = [HEAD.format(skips=skips)]
    parts.append("### Single-pod (16x16 = 256 chips)\n")
    parts.append(fmt_table(single))
    parts.append("\n### Multi-pod (2x16x16 = 512 chips)\n")
    parts.append(fmt_table(multi))
    ok_s = sum(1 for r in single if "error" not in r)
    ok_m = sum(1 for r in multi if "error" not in r)
    parts.append(
        f"\n**{ok_s}/{len(single)} single-pod and {ok_m}/{len(multi)} "
        "multi-pod cells compile.** Cells that exceed 16 GB/device are "
        "§Perf targets (see below).\n"
    )
    parts.append("\n## §Roofline — per (arch x shape), single-pod\n")
    parts.append(
        "Per-device seconds per step. One-line bottleneck notes follow "
        "the table.\n"
    )
    parts.append(fmt_roofline(single))

    notes_path = "dryrun/roofline_notes.md"
    if os.path.exists(notes_path):
        parts.append("\n" + open(notes_path).read())

    # optimized (beyond-paper) re-measurements vs the paper-faithful base
    opt = []
    for f in ("dryrun/single_pod_optimized.json",
              "dryrun/single_pod_optimized2.json"):
        if os.path.exists(f):
            opt.extend(json.load(open(f)))
    if opt:
        latest = {}
        for r in opt:
            if "error" not in r:
                latest[(r["arch"], r["shape"])] = r
        base = {(r["arch"], r["shape"]): r for r in single if "error" not in r}
        parts.append(
            "\n## §Roofline (optimized) — after the §Perf iterations\n\n"
            "Paper-faithful baselines above; the same cells after the "
            "beyond-paper optimizations (grouped shard-local MoE dispatch, "
            "step-boundary weight quant / bf16 FSDP gathers, chunkwise "
            "mLSTM, bf16 packed dequant, stacked-weight MXFP4 packing):\n"
        )
        hdr = ("| arch | shape | t_compute s | t_memory s (was) | "
               "t_collective s (was) | frac (was) |")
        parts.append(hdr + "\n|---|---|---|---|---|---|")
        for (a, s), r in sorted(latest.items()):
            b = base.get((a, s))
            if not b:
                continue
            parts.append(
                f"| {a} | {s} | {r['t_compute_s']:.3f} "
                f"| {r['t_memory_s']:.3f} ({b['t_memory_s']:.3f}) "
                f"| {r['t_collective_s']:.3f} ({b['t_collective_s']:.3f}) "
                f"| {r['roofline_fraction']:.4f} "
                f"({b['roofline_fraction']:.4f}) |"
            )
        parts.append("")
    mopt = "dryrun/multi_pod_optimized.json"
    if os.path.exists(mopt):
        rows = [r for r in json.load(open(mopt)) if "error" not in r]
        if rows:
            parts.append("\nMulti-pod MoE cells re-verified after the MoE "
                         "fixes (all compile):\n")
            parts.append(fmt_table(rows))

    perf_path = "dryrun/perf_log.json"
    parts.append("\n## §Perf — hypothesis -> change -> measure log\n")
    if os.path.exists(perf_path):
        for e in json.load(open(perf_path)):
            parts.append(
                f"### {e['cell']} — iteration {e['iter']}: {e['title']}\n\n"
                f"- **Hypothesis**: {e['hypothesis']}\n"
                f"- **Change**: {e['change']}\n"
                f"- **Before**: {e['before']}\n"
                f"- **After**: {e['after']}\n"
                f"- **Verdict**: {e['verdict']}\n"
            )
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts) + "\n")
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
