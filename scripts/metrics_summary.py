#!/usr/bin/env python
"""Pretty-print a serving metrics snapshot.

Reads the JSON snapshot written by ``--metrics-out`` (or, with
``--prom``, the Prometheus text exposition next to it) and renders a
terminal summary: counters/gauges as a table, histograms with
count/mean and p50/p90/p99, plus the request summary and SLO verdict
when the snapshot carries them.

Usage:
  PYTHONPATH=src python scripts/metrics_summary.py metrics.json
  PYTHONPATH=src python scripts/metrics_summary.py --prom metrics.prom
"""

from __future__ import annotations

import argparse
import json
import sys


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-3 or abs(v) >= 1e6:
            return f"{v:.3g}"
        return f"{v:.4f}".rstrip("0").rstrip(".")
    return str(v)


def _labelstr(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def summarize_json(snap: dict, out=sys.stdout) -> None:
    metrics = snap.get("metrics", snap)
    scalars, histos = [], []
    for name, fam in sorted(metrics.items()):
        for s in fam.get("series", []):
            row_name = name + _labelstr(s.get("labels", {}))
            if fam.get("type") == "histogram":
                histos.append((row_name, s))
            else:
                scalars.append((row_name, fam.get("type", "?"), s.get("value")))
    if scalars:
        w = max(len(n) for n, _, _ in scalars)
        print("-- counters / gauges", file=out)
        for name, kind, v in scalars:
            print(f"  {name:<{w}}  {kind:<7} {_fmt(v)}", file=out)
    if histos:
        w = max(len(n) for n, _ in histos)
        print("-- histograms (seconds unless named otherwise)", file=out)
        head = f"  {'':<{w}}  {'count':>7} {'mean':>10} {'p50':>10} " \
               f"{'p90':>10} {'p99':>10} {'max':>10}"
        print(head, file=out)
        for name, s in histos:
            mean = s["sum"] / s["count"] if s.get("count") else None
            print(
                f"  {name:<{w}}  {s.get('count', 0):>7} {_fmt(mean):>10} "
                f"{_fmt(s.get('p50')):>10} {_fmt(s.get('p90')):>10} "
                f"{_fmt(s.get('p99')):>10} {_fmt(s.get('max')):>10}",
                file=out,
            )
    req = snap.get("requests")
    if req:
        print("-- requests", file=out)
        print(f"  finished={req.get('n_requests')} "
              f"tokens={req.get('n_tokens')} "
              f"reasons={req.get('finish_reasons')}", file=out)
        for k in ("ttft_s", "queue_wait_s", "token_latency_s", "e2e_s"):
            p = req.get(k)
            if p:
                print(f"  {k:<16} p50={_fmt(p['p50'])} p90={_fmt(p['p90'])} "
                      f"p99={_fmt(p['p99'])} n={p['n']}", file=out)
    slo = snap.get("slo")
    if slo:
        verdict = "PASS" if slo.get("pass") else "FAIL"
        print(f"-- slo: {verdict}", file=out)
        for name, chk in (slo.get("checks") or {}).items():
            ok = {True: "ok", False: "VIOLATED", None: "no-data"}[chk["ok"]]
            print(f"  {name:<16} target={_fmt(chk['target_s'])} "
                  f"observed={_fmt(chk['observed_s'])} {ok}", file=out)


def summarize_prom(text: str, out=sys.stdout) -> None:
    from repro.obs import parse_prometheus

    samples = parse_prometheus(text)
    w = max(
        (len(n + _labelstr(dict(ls))) for (n, ls) in samples), default=0
    )
    for (name, labels), v in sorted(samples.items()):
        print(f"  {name + _labelstr(dict(labels)):<{w}}  {_fmt(v)}",
              file=out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="metrics snapshot (.json, or .prom "
                                 "with --prom)")
    ap.add_argument("--prom", action="store_true",
                    help="input is a Prometheus text exposition")
    args = ap.parse_args(argv)
    try:
        with open(args.path) as f:
            if args.prom:
                summarize_prom(f.read())
            else:
                summarize_json(json.load(f))
    except BrokenPipeError:  # e.g. piped into head
        sys.exit(0)


if __name__ == "__main__":
    main()
