#!/usr/bin/env python
"""Per-layer numerical-fidelity report.

Reads either a serving metrics snapshot (written by
``repro.launch.serve --fidelity --metrics-out PATH``) or the
``BENCH_fidelity.json`` artifact from ``benchmarks/run.py --only
fidelity_sweep`` (autodetected) and renders per-layer tables: SQNR vs
the reference forward, MXFP4 clip/underflow ratios, ADC saturation,
calibration headroom (exponent margin + full-scale ratio) and the drift
verdict, worst layers first. Pure stdlib — no repro import needed.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --tiny --backend cim \
      --fidelity --metrics-out metrics.json
  python scripts/fidelity_report.py metrics.json
  python scripts/fidelity_report.py BENCH_fidelity.json --top 10
"""

from __future__ import annotations

import argparse
import json
import math
import sys

COLS = (
    ("sqnr_db", "sqnr_dB"),
    ("clip_ratio", "clip"),
    ("underflow_ratio", "uflow"),
    ("adc_saturation_ratio", "adc_sat"),
    ("exp_margin", "e_margin"),
    ("fs_headroom", "fs_ratio"),
)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if math.isnan(v):
            return "NaN"
        if math.isinf(v):
            return "inf"
        if v == 0:
            return "0"
        if abs(v) < 1e-3 or abs(v) >= 1e5:
            return f"{v:.3g}"
        return f"{v:.4f}".rstrip("0").rstrip(".")
    return str(v)


def _sort_key(row):
    # worst first: drifted layers, then ascending SQNR (None last)
    db = row[1].get("sqnr_db")
    return (not row[1].get("drifted", False),
            math.inf if db is None else db)


def print_layers(layers: dict, drifted=(), out=sys.stdout) -> None:
    rows = [(p, dict(e, drifted=p in drifted)) for p, e in layers.items()]
    rows.sort(key=_sort_key)
    w = max((len(p) for p, _ in rows), default=5)
    head = "  ".join(f"{h:>8}" for _, h in COLS)
    print(f"  {'layer':<{w}}  {head}  drift", file=out)
    for path, e in rows:
        vals = "  ".join(f"{_fmt(e.get(k)):>8}" for k, _ in COLS)
        mark = "DRIFT" if e.get("drifted") else ""
        print(f"  {path:<{w}}  {vals}  {mark}", file=out)


def summarize_metrics(snap: dict, out=sys.stdout) -> None:
    """Rebuild the per-layer table from the fidelity metric families of a
    serving metrics snapshot."""
    metrics = snap.get("metrics", snap)
    fam_to_col = {
        "fidelity_sqnr_db": "sqnr_db",
        "fidelity_mxfp4_clip_ratio": "clip_ratio",
        "fidelity_mxfp4_underflow_ratio": "underflow_ratio",
        "adc_saturation_ratio": "adc_saturation_ratio",
        "fidelity_drift_exp_margin": "exp_margin",
        "fidelity_drift_fs_ratio": "fs_headroom",
    }
    layers: dict = {}
    for fam_name, col in fam_to_col.items():
        fam = metrics.get(fam_name)
        for s in (fam or {}).get("series", []):
            layer = s.get("labels", {}).get("layer")
            if layer is not None:
                # to_json writes NaN as null; keep the sentinel visible
                v = s.get("value")
                layers.setdefault(layer, {})[col] = (
                    math.nan if v is None else v
                )
    if not layers:
        print("no fidelity metrics in snapshot (run serve with "
              "--fidelity)", file=out)
        return
    drift = metrics.get("fidelity_drift_total")
    n_drift = sum(s.get("value", 0) for s in (drift or {}).get("series", []))
    # the snapshot keeps verdicts only in aggregate; recover per-layer
    # flags conservatively from the published counters being non-zero
    print(f"-- fidelity: {len(layers)} layers, "
          f"{int(n_drift)} drifted", file=out)
    print_layers(layers, out=out)


def summarize_bench(doc: dict, top: int | None, out=sys.stdout) -> None:
    for model, entry in doc.get("models", {}).items():
        for variant, rep in entry.get("variants", {}).items():
            lay = rep.get("layers", {})
            if top:
                keep = sorted(
                    lay.items(),
                    key=lambda r: _sort_key((r[0],
                                             dict(r[1],
                                                  drifted=r[0] in
                                                  rep.get("drifted", ())))),
                )[:top]
                lay = dict(keep)
            print(f"-- {model} / {variant}: output "
                  f"{_fmt(rep.get('output_sqnr_db'))} dB, "
                  f"{rep.get('n_drifted', 0)} drifted", file=out)
            print_layers(lay, drifted=rep.get("drifted", ()), out=out)
        ov = entry.get("overhead")
        if ov:
            print(f"-- {model} probe overhead: "
                  f"{_fmt(ov.get('ratio'))}x eager "
                  f"({_fmt(ov.get('fidelity_on_ms'))} ms vs "
                  f"{_fmt(ov.get('fidelity_off_ms'))} ms)", file=out)
    gate = doc.get("gate")
    if gate:
        print("-- gate:", " ".join(f"{k}={_fmt(v)}"
                                   for k, v in gate.items()), file=out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="metrics snapshot .json or "
                                 "BENCH_fidelity.json")
    ap.add_argument("--top", type=int, default=None,
                    help="show only the N worst layers per table")
    args = ap.parse_args(argv)
    try:
        with open(args.path) as f:
            doc = json.load(f)
        if "models" in doc:  # BENCH_fidelity.json artifact
            summarize_bench(doc, args.top)
        else:
            summarize_metrics(doc)
    except BrokenPipeError:  # e.g. piped into head
        sys.exit(0)


if __name__ == "__main__":
    main()
