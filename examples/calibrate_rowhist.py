"""Row-Hist calibration walkthrough (paper §3.2.1, Figs 5/6):
calibrate per-layer target exponents on representative batches, then show
how CM-bit budget and the 2-pass scheme trade saturation for fidelity.

Run:  PYTHONPATH=src python examples/calibrate_rowhist.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim, mx
from repro.obs import sqnr_db

rng = np.random.default_rng(0)
layers = {
    "qkv_proj": (768, 768),
    "ffn_up": (768, 3072),
    "ffn_down": (3072, 768),
}
# 5 representative calibration batches (paper uses 5)
batches = [
    jnp.asarray(rng.standard_normal((32, 3072)).astype(np.float32))
    for _ in range(5)
]

print(f"{'layer':10s} {'E_N':>5s} {'ADC FS':>10s} "
      f"{'underflow@CM3(2p)':>18s} {'SQNR dB':>8s}")
for name, (k, m) in layers.items():
    w = jnp.asarray(rng.standard_normal((k, m)).astype(np.float32) * k**-0.5)
    wq = mx.quantize_w(w)
    xs = [b[:, :k] for b in batches]
    cfg = cim.CIMConfig(adc_bits=10, cm_bits=3, two_pass=True,
                        collect_stats=True)
    calib = cim.calibrate_rowhist(xs, wq, cfg)
    y, st = cim.cim_linear(xs[0], wq, cfg, calib)
    ref = mx.dequantize(mx.quantize(xs[0]), out_len=k) @ mx.dequantize_w(wq)
    sqnr = sqnr_db(ref, y)
    print(f"{name:10s} {int(calib.e_n):5d} {float(calib.adc_fs):10.1f} "
          f"{float(st['underflow_rate_p2']):18.4f} {sqnr:8.1f}")

print("\nCM sweep on ffn_up (Fig 5/6 shape):")
w = jnp.asarray(rng.standard_normal((768, 3072)).astype(np.float32) * 768**-0.5)
wq = mx.quantize_w(w)
xs = [b[:, :768] for b in batches]
ref = mx.dequantize(mx.quantize(xs[0]), out_len=768) @ mx.dequantize_w(wq)
for cmb in (1, 2, 3, 4, 5):
    for two in (False, True):
        cfg = cim.CIMConfig(adc_bits=None, cm_bits=cmb, two_pass=two,
                            collect_stats=True)
        calib = cim.calibrate_rowhist(xs, wq, cfg)
        y, st = cim.cim_linear(xs[0], wq, cfg, calib)
        sqnr = sqnr_db(ref, y)
        print(f"CM={cmb} {'2-pass' if two else '1-pass'}: "
              f"underflow={float(st['underflow_rate_p1' if not two else 'underflow_rate_p2']):.3f} "
              f"SQNR={sqnr:6.1f} dB")
