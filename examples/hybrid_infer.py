"""End-to-end hybrid analog/digital CIM inference (paper §4 deployment).

Walks the full offline->serving flow on a tiny model:

1. digital baseline: fully-digital MXFP4 accelerator sim
   (``quant="mxfp4_digital"``: W+A quantized linears + MXFP4 SDPA),
2. Row-Hist calibration: representative batches -> per-static-linear
   target exponent E_N + ADC full scale, keyed by param-tree path,
3. conversion: static linears -> resident INT5 codes + exps + calib
   (the analog CTT arrays), MoE experts -> packed digital MXFP4,
4. hybrid forward + greedy decode on the ``cim_analog`` backend, and the
   digital-vs-CIM logit/accuracy deltas (the paper's <1% claim, scaled).

Run:  PYTHONPATH=src python examples/hybrid_infer.py [--arch h2o-danube-1.8b]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core import cim as cimlib
from repro.core.metrics import sqnr_db
from repro.layers import backends
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import calibrate, lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--adc-bits", type=int, default=10)
    ap.add_argument("--cm-bits", type=int, default=3)
    args = ap.parse_args()

    cfg = C.tiny(C.ARCHS[args.arch])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
    cim_cfg = cimlib.CIMConfig(adc_bits=args.adc_bits, cm_bits=args.cm_bits,
                               two_pass=True)

    batches = calibrate.calibration_batches(
        cfg, n_batches=3, batch=args.batch, seq=args.seq
    )
    t0 = time.time()
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, ctx, batches, cim_cfg=cim_cfg, min_n=32
    )
    n_analog = len(calibs)
    print(f"== offline: Row-Hist calibrated {n_analog} static linears "
          f"({time.time() - t0:.1f}s) ==")
    for path in sorted(calibs)[:6]:
        c = calibs[path]
        print(f"  {path:28s} E_N={int(c.e_n):3d}  ADC_FS={float(c.adc_fs):9.1f}")
    if n_analog > 6:
        print(f"  ... and {n_analog - 6} more")

    eval_batch = batches[0]
    float_ctx = ctx
    dig_ctx = dataclasses.replace(ctx, quant="mxfp4_digital")
    hyb_ctx = dataclasses.replace(ctx, quant="cim", cim=cim_cfg)

    f_logits, _ = lm.forward(params, cfg, float_ctx, eval_batch)
    d_logits, _ = lm.forward(params, cfg, dig_ctx, eval_batch)
    h_logits, _ = lm.forward(conv, cfg, hyb_ctx, eval_batch)
    f = np.asarray(f_logits, np.float32)
    d = np.asarray(d_logits, np.float32)
    h = np.asarray(h_logits, np.float32)

    print("\n== logit fidelity (tiny random-init model; worst case) ==")
    print(f"digital MXFP4 vs bf16 float : SQNR {sqnr_db(f, d):6.1f} dB, "
          f"top-1 agree {(f.argmax(-1) == d.argmax(-1)).mean():.2%}")
    print(f"hybrid CIM    vs bf16 float : SQNR {sqnr_db(f, h):6.1f} dB, "
          f"top-1 agree {(f.argmax(-1) == h.argmax(-1)).mean():.2%}")
    print(f"hybrid CIM    vs digital    : SQNR {sqnr_db(d, h):6.1f} dB, "
          f"top-1 agree {(d.argmax(-1) == h.argmax(-1)).mean():.2%}  "
          f"<- the paper's analog-vs-digital delta")

    # lossless sanity: no ADC + unbounded mirror window == digital exactly.
    # The converted tree is config-independent (E_N from Row-Hist, adc_fs
    # unused when the ADC is off), so reuse the calibs — no second capture.
    lossless = cimlib.CIMConfig(adc_bits=None, cm_bits=64, two_pass=False)
    conv0 = backends.convert_params_cim(params, calibs, min_n=32)
    # unrolled op-by-op execution on both sides: XLA scan fusion flips
    # MXFP4 codes at 1-ulp boundaries between different graphs, so the
    # bitwise identity only shows outside lax.scan
    h0, _ = lm.forward(conv0, cfg,
                       dataclasses.replace(hyb_ctx, cim=lossless,
                                           unroll_layers=True), eval_batch)
    d0, _ = lm.forward(params, cfg,
                       dataclasses.replace(dig_ctx, unroll_layers=True),
                       eval_batch)
    print(f"lossless CIM  vs digital    : SQNR "
          f"{sqnr_db(np.asarray(d0, np.float32), np.asarray(h0, np.float32)):6.1f}"
          f" dB (exact wiring)")

    print(f"\n== hybrid greedy decode ({args.tokens} tokens) ==")
    b, s = eval_batch["ids"].shape
    caches = lm.init_cache(cfg, b, s + args.tokens)
    logits, caches = lm.forward(conv, cfg, hyb_ctx, eval_batch, caches=caches)
    ids = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]
    step = jax.jit(
        lambda p, c, i, pos: lm.decode_step(p, cfg, hyb_ctx, i, pos, c)
    )
    outs, t0 = [ids], time.time()
    for t in range(args.tokens - 1):
        lo, caches = step(conv, caches, ids, jnp.int32(s + t))
        ids = jnp.argmax(lo.astype(jnp.float32), -1)[:, None]
        outs.append(ids)
    dt = time.time() - t0
    print(f"decoded {(args.tokens - 1) * b} tokens in {dt:.2f}s; "
          f"ids[0] = {jnp.concatenate(outs, 1)[0].tolist()}")


if __name__ == "__main__":
    main()
