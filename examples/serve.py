"""Batched serving demo: MXFP4 weight-only (packed, 4.25 b/param resident)
prefill + greedy decode with KV caches — the FWS deployment mode.

Run:  PYTHONPATH=src python examples/serve.py --tokens 24
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = C.tiny(C.ARCHS[args.arch])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params = convert_params_mxfp4(params)  # resident MXFP4 weights
    nbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} resident weights {nbytes/1e6:.2f} MB (packed MXFP4)")

    ctx = RunCtx(shd=ShardingCtx(), quant="mxfp4_wonly", dense_attn_max=256)
    max_len = args.prompt_len + args.tokens
    caches = lm.init_cache(cfg, args.batch, max_len)

    # prefill the prompt into the caches
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    hidden, caches = lm.forward(
        params, cfg, ctx, {"ids": prompt}, caches=caches, return_hidden=True
    )
    from repro.launch.steps import _head_logits

    logits = _head_logits(cfg, params, hidden[:, -1])
    next_ids = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
    print(f"prefill {args.prompt_len} tokens x{args.batch}: "
          f"{time.time()-t0:.2f}s")

    step = jax.jit(
        lambda p, c, i, pos: lm.decode_step(p, cfg, ctx, i, pos, c)
    )
    seqs = [next_ids]
    t0 = time.time()
    for t in range(args.tokens - 1):
        logits, caches = step(params, caches, next_ids,
                              jnp.int32(args.prompt_len + t))
        next_ids = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
        seqs.append(next_ids)
    dt = time.time() - t0
    out = jnp.concatenate(seqs, axis=1)
    print(f"decoded {args.tokens-1} steps x{args.batch} seqs in {dt:.2f}s "
          f"({(args.tokens-1)*args.batch/dt:.1f} tok/s on CPU interpret)")
    print("sampled ids[0]:", out[0].tolist())


if __name__ == "__main__":
    main()
