"""End-to-end training driver: synthetic-data LM training with the full
substrate (pipeline, AdamW, async checkpointing, fault-tolerant trainer,
MXFP4-STE quantized training).

Presets:
  tiny  (~2M params, CPU-friendly smoke: default here)
  100m  (~100M params — the brief's reference run; intended for TPU, works
         on CPU but slowly)

Run:  PYTHONPATH=src python examples/train_tinylm.py --steps 60
"""

import argparse
import dataclasses

import jax

from repro import configs as C
from repro.layers.common import RunCtx, ShardingCtx
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 head_dim=32, d_ff=384, vocab_size=512, window=64),
    "100m": dict(n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                 head_dim=64, d_ff=1792, vocab_size=32000, window=1024),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_tinylm")
    ap.add_argument("--quant", default="mxfp4_ste",
                    choices=["none", "mxfp4_ste"])
    args = ap.parse_args()

    cfg = dataclasses.replace(C.ARCHS["h2o-danube-1.8b"], **PRESETS[args.preset])
    shape = C.Shape(seq=args.seq, batch=args.batch, kind="train")
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(
                lambda: __import__("repro.models.lm", fromlist=["lm"])
                .init_model(jax.random.PRNGKey(0), cfg)[0]
            )
        )
    )
    print(f"arch={cfg.name} preset={args.preset} params={n_params/1e6:.1f}M "
          f"quant={args.quant}")

    ctx = RunCtx(shd=ShardingCtx(), quant=args.quant, dense_attn_max=512)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=20,
                         ckpt_dir=args.ckpt,
                         log_path=args.ckpt + ".metrics.jsonl")
    trainer = Trainer(cfg, shape, tcfg, ctx=ctx,
                      opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                                                total_steps=args.steps))
    result = trainer.run()
    losses = result["losses"]
    print(f"steps {trainer.start_step}->{result['final_step']}  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"slow steps flagged: {len(result['slow_steps'])}")
    assert losses[-1] < losses[0], "loss must decrease"
    print("ok: loss decreased; checkpoint committed at",
          trainer.ckpt.latest_step())


if __name__ == "__main__":
    main()
