"""Vision walkthrough: ViT classification, float vs hybrid analog CIM.

The encoder analogue of ``examples/hybrid_infer.py``:

1. init a tiny ViT (patch-embed -> CLS + learned positions -> pre-LN
   encoder blocks -> classification head, all through the backend
   registry),
2. Row-Hist calibrate on synthetic representative images and convert the
   static linears (patch embedding, QKV/O, FFN, head) to resident analog
   CTT arrays,
3. classify a batch of synthetic images under float / digital MXFP4 /
   hybrid CIM and report logit fidelity + top-1 agreement (the paper's
   <1% accuracy-preservation claim, scaled to a random-init smoke model).

Run:  PYTHONPATH=src python examples/classify.py [--arch vit-b16]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs as C
from repro.core import cim as cimlib
from repro.core.metrics import sqnr_db
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import calibrate, vit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-b16",
                    choices=sorted(C.VISION_ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--adc-bits", type=int, default=10)
    ap.add_argument("--cm-bits", type=int, default=3)
    ap.add_argument("--geometry-true", action="store_true",
                    help="keep the paper's patch grid / layer count "
                         "(slower; default is the fully tiny config)")
    args = ap.parse_args()

    full = C.VISION_ARCHS[args.arch]
    cfg = (C.geometry_tiny_vit(full) if args.geometry_true
           else C.tiny_vit(full))
    print(f"== {cfg.name}: {cfg.n_layers} layers, d={cfg.d_model}, "
          f"{cfg.seq_len} tokens ({cfg.grid}x{cfg.grid} patches + CLS) ==")

    params, _ = vit.init_model(jax.random.PRNGKey(0), cfg)
    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
    cim_cfg = cimlib.CIMConfig(adc_bits=args.adc_bits,
                               cm_bits=args.cm_bits, two_pass=True)

    batches = vit.calibration_images(cfg, n_batches=2, batch=args.batch)
    t0 = time.time()
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, ctx, batches, cim_cfg=cim_cfg, min_n=32,
        forward_fn=vit.forward,
    )
    print(f"row-hist calibrated {len(calibs)} static linears "
          f"(patch embed, per-layer QKV/O + FFN, head) in "
          f"{time.time() - t0:.1f}s")

    images = vit.calibration_images(cfg, n_batches=1, batch=args.batch,
                                    seed=99)[0]
    fl, _ = vit.forward(params, cfg, ctx, images)
    dg, _ = vit.forward(
        params, cfg, dataclasses.replace(ctx, quant="mxfp4_digital"), images
    )
    hy, _ = vit.forward(
        conv, cfg, dataclasses.replace(ctx, quant="cim", cim=cim_cfg), images
    )
    f = np.asarray(fl, np.float32)
    d = np.asarray(dg, np.float32)
    h = np.asarray(hy, np.float32)
    print(f"float  top-1: {f.argmax(-1).tolist()}")
    print(f"mxfp4  top-1: {d.argmax(-1).tolist()}  "
          f"(SQNR vs float {sqnr_db(f, d):.1f} dB)")
    print(f"cim    top-1: {h.argmax(-1).tolist()}  "
          f"(SQNR vs mxfp4 {sqnr_db(d, h):.1f} dB, vs float "
          f"{sqnr_db(f, h):.1f} dB)")
    agree = float((f.argmax(-1) == h.argmax(-1)).mean())
    print(f"float<->cim top-1 agreement: {agree:.2f} "
          f"(paper: <1pp accuracy drop on trained models)")


if __name__ == "__main__":
    main()
