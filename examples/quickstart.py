"""Quickstart: MXFP4 microscaling + the analog CTT-CIM path in 2 minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim, digital, mx

print("== 1. MXFP4 block quantization (32 x E2M1 + shared E8M0) ==")
x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 3.0
q = mx.quantize(x)
deq = mx.dequantize(q, out_len=64)
print(f"codes int8 in [-12,12]: {np.asarray(q.codes)[0, :8]}")
print(f"shared exponents:       {np.asarray(q.exps)[0]}")
print(f"quantization rel-err:   {float(jnp.linalg.norm(deq - x) / jnp.linalg.norm(x)):.4f}")
packed = mx.pack_codes(q.codes)
print(f"packed storage: {q.codes.shape} int8 -> {packed.shape} uint8 "
      f"(4.25 bits/param with scales)\n")

print("== 2. Analog CTT-CIM linear (Row-Hist 2-pass, CM=3, 10-bit ADC) ==")
w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * 0.2
wq = mx.quantize_w(w)
cfg = cim.CIMConfig(adc_bits=10, cm_bits=3, two_pass=True, collect_stats=True)
calib = cim.calibrate_rowhist([x], wq, cfg)
print(f"calibrated per-layer target exponent E_N = {int(calib.e_n)}, "
      f"ADC full-scale = {float(calib.adc_fs):.1f}")
y_analog, stats = cim.cim_linear(x, wq, cfg, calib)
y_digital = mx.dequantize(mx.quantize(x), out_len=64) @ mx.dequantize_w(wq)
err = float(jnp.linalg.norm(y_analog - y_digital) / jnp.linalg.norm(y_digital))
print(f"analog vs digital-MXFP4 rel-err: {err:.4f} "
      f"(overflow rate {float(stats['overflow_rate']):.3f})\n")

print("== 3. Digital-stage attention (MXFP4 ops, BF16 accum, flash softmax) ==")
q_, k_, v_ = (jax.random.normal(jax.random.PRNGKey(i), (1, 32, 16))
              for i in (2, 3, 4))
out = digital.mx_attention(q_, k_, v_, causal=True)
ref = digital.attention_ref(q_, k_, v_, causal=True)
print(f"attention rel-err vs fp32: "
      f"{float(jnp.linalg.norm(out.astype(jnp.float32) - ref) / jnp.linalg.norm(ref)):.4f}")

print("\n== 4. Pallas kernels (interpret mode on CPU; TPU is the target) ==")
from repro.kernels.mxfp4_matmul import ops as mm_ops

out_k = mm_ops.mxfp4_matmul(
    x.astype(jnp.bfloat16), mx.pack_codes(wq.codes.T).T,
    mx.exps_to_biased(wq.exps), interpret=True,
)
rel = float(
    jnp.linalg.norm(out_k.astype(jnp.float32) - y_digital)
    / jnp.linalg.norm(y_digital)
)
print(f"fused dequant-matmul kernel rel-err vs digital: {rel:.4f} "
      f"(bf16 output rounding)")
print("done.")
