"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim as cimlib
from repro.core import mx as mxlib
from repro.kernels.cim_linear import ops as cim_ops
from repro.kernels.cim_linear import ref as cim_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.mxfp4_matmul import ops as mm_ops
from repro.kernels.mxfp4_matmul import ref as mm_ref


def _packed_weight(key, k, n):
    w = jax.random.normal(key, (k, n), jnp.float32)
    wq = mxlib.quantize_w(w)
    codes = mxlib.pack_codes(wq.codes.T).T
    exps = mxlib.exps_to_biased(wq.exps)
    return w, wq, codes, exps


@pytest.mark.parametrize(
    "m,k,n", [(8, 64, 16), (128, 128, 128), (33, 96, 48), (256, 512, 64)]
)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_mxfp4_matmul_sweep(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + k + n))
    x = jax.random.normal(kx, (m, k), dtype)
    _, _, codes, exps = _packed_weight(kw, k, n)
    out = mm_ops.mxfp4_matmul(x, codes, exps, interpret=True)
    ref = mm_ref.mxfp4_matmul_ref(x, codes, exps)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2 * np.abs(np.asarray(ref, np.float32)).max(),
    )


def test_mxfp4_matmul_batched_and_bitexact_dequant():
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (2, 3, 64), jnp.bfloat16)
    _, wq, codes, exps = _packed_weight(kw, 64, 32)
    out = mm_ops.mxfp4_matmul(x, codes, exps, interpret=True)
    assert out.shape == (2, 3, 32)
    # dequant path in ref == core mx dequant (bit exact)
    d1 = np.asarray(mm_ref.dequant_ref(codes, exps))
    d2 = np.asarray(mxlib.dequantize_w(wq))
    np.testing.assert_array_equal(d1, d2)


@pytest.mark.parametrize("m,k,n", [(16, 64, 16), (64, 128, 32)])
@pytest.mark.parametrize("adc,cm,two", [(10, 3, True), (None, 2, False), (8, 4, True)])
def test_cim_linear_kernel_matches_sim(m, k, n, adc, cm, two):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n + cm))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    wq = mxlib.quantize_w(w)
    cfg = cimlib.CIMConfig(adc_bits=adc, cm_bits=cm, two_pass=two)
    calib = cimlib.calibrate_rowhist([x], wq, cfg)
    out = cim_ops.cim_linear(x, wq, calib, cfg=cfg, interpret=True)
    ref = cim_ref.cim_linear_ref(x, wq, calib, cfg)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("sq,sk,h,hkv,d", [
    (32, 32, 4, 4, 16),
    (64, 64, 8, 2, 32),
    (33, 48, 4, 1, 16),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_flash_attention_sweep(sq, sk, h, hkv, d, causal, window):
    if sq != sk and causal:
        return  # self-attention shapes only for causal sweep
    keys = jax.random.split(jax.random.PRNGKey(sq + h + window), 3)
    q = jax.random.normal(keys[0], (2, sq, h, d), jnp.float32)
    k = jax.random.normal(keys[1], (2, sk, hkv, d), jnp.float32)
    v = jax.random.normal(keys[2], (2, sk, hkv, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 interpret=True)
    ref = fa_ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )


def test_flash_attention_bf16_and_offset():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 16, 4, 32), jnp.bfloat16)
    k = jax.random.normal(keys[1], (1, 64, 4, 32), jnp.bfloat16)
    v = jax.random.normal(keys[2], (1, 64, 4, 32), jnp.bfloat16)
    # q is the last 16 positions of a 64-long sequence
    out = fa_ops.flash_attention(q, k, v, causal=True, q_offset=48,
                                 interpret=True)
    ref = fa_ref.flash_attention_ref(q, k, v, causal=True, q_offset=48)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_kernel_lowers_for_tpu_shapes():
    """The kernels must at least lower (trace) without interpret mode
    errors at TPU-aligned shapes."""
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    x = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    codes = jax.ShapeDtypeStruct((256, 256), jnp.uint8)
    exps = jax.ShapeDtypeStruct((16, 256), jnp.uint8)
    jax.eval_shape(
        lambda a, c, e: mm_ops.mxfp4_matmul(a, c, e, interpret=True),
        x, codes, exps,
    )
