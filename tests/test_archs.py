"""Per-architecture smoke tests: reduced same-family configs, one forward
+ one train-loss(+grad) step + (where applicable) one decode step on CPU,
asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import lm

CTX = RunCtx(shd=ShardingCtx(), dense_attn_max=64, attn_chunk=16, q_chunk=16)
SMOKE_SHAPE = C.Shape(seq=32, batch=2, kind="train")


def _build(arch_name):
    cfg = C.tiny(C.ARCHS[arch_name])
    params, specs = lm.init_model(jax.random.PRNGKey(0), cfg)
    # specs mirror params
    jax.tree.map(lambda p, s: None, params,
                 jax.tree.map(lambda x: 0, specs,
                              is_leaf=lambda x: isinstance(x, tuple)))
    return cfg, params


@pytest.mark.parametrize("arch", sorted(C.ARCHS))
def test_forward_and_loss(arch):
    cfg, params = _build(arch)
    batch = C.concrete_inputs(cfg, SMOKE_SHAPE)
    logits, _ = lm.forward(params, cfg, CTX, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, cfg, CTX, batch, chunk=16)
    )(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x))), grads, 0.0
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize(
    "arch", sorted(a for a, c in C.ARCHS.items() if c.supports_decode)
)
def test_decode_step(arch):
    cfg, params = _build(arch)
    caches = lm.init_cache(cfg, batch=2, max_len=32)
    ids = jnp.array([[3], [5]], jnp.int32)
    logits, caches2 = lm.decode_step(params, cfg, CTX, ids, jnp.int32(0), caches)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    logits3, _ = lm.decode_step(params, cfg, CTX, ids, jnp.int32(1), caches2)
    assert np.all(np.isfinite(np.asarray(logits3, np.float32)))


def test_decode_matches_prefill_dense():
    """Teacher-forced decode == prefill logits (causal dense arch)."""
    cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    params, _ = lm.init_model(jax.random.PRNGKey(1), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, CTX, {"ids": ids})
    caches = lm.init_cache(cfg, batch=1, max_len=16)
    outs = []
    for t in range(8):
        lg, caches = lm.decode_step(
            params, cfg, CTX, ids[:, t : t + 1], jnp.int32(t), caches
        )
        outs.append(np.asarray(lg, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(full_logits, np.float32), rtol=3e-2, atol=3e-2
    )


def test_decode_matches_prefill_ssm():
    """Recurrent decode == chunked-parallel prefill (xLSTM + Mamba paths)."""
    for arch in ("xlstm-125m", "zamba2-1.2b"):
        cfg = C.tiny(C.ARCHS[arch])
        params, _ = lm.init_model(jax.random.PRNGKey(3), cfg)
        ids = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0, cfg.vocab_size)
        full_logits, _ = lm.forward(params, cfg, CTX, {"ids": ids})
        caches = lm.init_cache(cfg, batch=1, max_len=8)
        for t in range(6):
            lg, caches = lm.decode_step(
                params, cfg, CTX, ids[:, t : t + 1], jnp.int32(t), caches
            )
        np.testing.assert_allclose(
            lg, np.asarray(full_logits, np.float32)[:, -1], rtol=5e-2, atol=5e-2
        )


def test_mxfp4_ste_quant_mode_runs():
    cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    params, _ = lm.init_model(jax.random.PRNGKey(5), cfg)
    ctx = RunCtx(shd=ShardingCtx(), quant="mxfp4_ste", dense_attn_max=64)
    batch = C.concrete_inputs(cfg, SMOKE_SHAPE)
    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, cfg, ctx, batch, chunk=16)
    )(params)
    assert np.isfinite(float(loss))


def test_flash_attention_path_matches_dense():
    cfg = C.tiny(C.ARCHS["starcoder2-7b"])
    params, _ = lm.init_model(jax.random.PRNGKey(6), cfg)
    batch = C.concrete_inputs(cfg, SMOKE_SHAPE)
    dense_ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=64)
    flash_ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=8, attn_chunk=16,
                       q_chunk=16)
    l1, _ = lm.forward(params, cfg, dense_ctx, batch)
    l2, _ = lm.forward(params, cfg, flash_ctx, batch)
    # bf16 accumulation-order noise only
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32),
        rtol=5e-2, atol=8e-2,
    )


def test_swa_window_masks_work():
    """SWA forward differs from full attention (window actually applied)."""
    cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    import dataclasses

    cfg_full = dataclasses.replace(cfg, attn_pattern="full")
    params, _ = lm.init_model(jax.random.PRNGKey(7), cfg)
    batch = C.concrete_inputs(cfg, SMOKE_SHAPE)
    l_swa, _ = lm.forward(params, cfg, CTX, batch)
    l_full, _ = lm.forward(params, cfg_full, CTX, batch)
    assert not np.allclose(np.asarray(l_swa), np.asarray(l_full))


def test_mlstm_chunkwise_equals_sequential():
    """Chunkwise-parallel mLSTM == sequential scan (the §Perf rewrite)."""
    import jax
    from repro.layers import xlstm as xl

    b, s, h, dk = 2, 50, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    qf = jax.random.normal(ks[0], (b, s, h, dk))
    kf = jax.random.normal(ks[1], (b, s, h, dk)) * dk**-0.5
    vf = jax.random.normal(ks[2], (b, s, h, dk))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)) + 1.0)
    init = (
        jnp.zeros((b, h, dk, dk)),
        jnp.zeros((b, h, dk)),
        jnp.full((b, h), -1e30),
    )
    scale = dk**-0.5
    hc, (c2, n2, m2) = xl._mlstm_chunkwise(qf, kf, vf, ig, fg, init, scale,
                                           chunk=16)
    (c1, n1, m1), hs = jax.lax.scan(
        lambda c, i: xl._mlstm_step(c, i, scale),
        init,
        tuple(a.swapaxes(0, 1) for a in (qf, kf, vf, ig, fg)),
    )
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs.swapaxes(0, 1)),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c1), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m1), rtol=2e-4)
