"""Real multi-device FWS pipeline executor (distributed/pipeline_exec.py).

Parity contract: the pipelined shard_map forward must match the
single-device forward — bitwise for float and packed-MXFP4 (both permit
it: the stage body replays the exact ``lm._run_segment`` scan), and
SQNR-bounded for cim (integer clip/shift chains can flip 1-ulp under
different fusion; in practice it is bitwise on CPU too).

Transfer guard: the steady-state trunk step's compiled HLO may contain
ONLY ``collective-permute`` (the stage-to-stage activation hop) and its
wire traffic must be activation-sized — orders below the resident trunk
bytes. That is the executable form of the paper's weights-never-move FWS
premise.

Stage counts adapt to the visible device mesh: under the plain tier-1 run
(1 device) the single-stage degenerate path is covered; the CI
multi-device job re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the real >= 4
stage coverage (see .github/workflows/ci.yml).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.core import cim as cimlib
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, lm, vit
from repro.distributed import pipeline_exec as pex

N_DEV = jax.device_count()
STAGES = max(s for s in (1, 2, 4) if s <= N_DEV)
CTX = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
B, S = 3, 16

needs_multidev = pytest.mark.skipif(
    N_DEV < 2, reason="needs a multi-device platform "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
)


def _sqnr_db(ref, out):
    ref = jnp.asarray(ref, jnp.float32)
    out = jnp.asarray(out, jnp.float32)
    err = jnp.sum((ref - out) ** 2)
    return float(10 * jnp.log10(jnp.sum(ref * ref) / jnp.maximum(err, 1e-30)))


@pytest.fixture(scope="module")
def lm_setup():
    cfg = dataclasses.replace(C.tiny(C.ARCHS["starcoder2-7b"]), n_layers=4)
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
    return cfg, params, {"ids": ids}


@pytest.fixture(scope="module")
def vit_setup():
    cfg = dataclasses.replace(C.tiny_vit(C.VISION_ARCHS["vit-b16"]),
                              n_layers=4)
    params, _ = vit.init_model(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.image_size, cfg.image_size, 3),
        jnp.float32,
    )
    return cfg, params, {"images": imgs}


# ------------------------------------------------------------- LM parity

@pytest.mark.parametrize(
    "microbatches,mb_size",
    [(1, 3), (2, 2), (3, 1)],  # (2, 2): capacity 4 > B=3, ragged final mb
)
def test_lm_pipeline_parity_float(lm_setup, microbatches, mb_size):
    cfg, params, batch = lm_setup
    ref, _ = jax.jit(lambda p, b: lm.forward(p, cfg, CTX, b))(params, batch)
    pipe = pex.build_lm_pipeline(
        params, cfg, CTX, stages=STAGES, microbatches=microbatches,
        mb_size=mb_size,
    )
    out = pipe.forward(batch)
    assert out.shape == ref.shape
    assert bool((out == ref).all()), (
        f"float pipeline not bitwise: sqnr {_sqnr_db(ref, out):.1f} dB"
    )


def test_lm_pipeline_parity_mxfp4(lm_setup):
    cfg, params, batch = lm_setup
    qparams = convert_params_mxfp4(params, min_n=32)
    qctx = dataclasses.replace(CTX, quant="mxfp4_wonly")
    ref, _ = jax.jit(lambda p, b: lm.forward(p, cfg, qctx, b))(qparams, batch)
    pipe = pex.build_lm_pipeline(
        qparams, cfg, qctx, stages=STAGES, microbatches=2, mb_size=2,
    )
    out = pipe.forward(batch)
    # cross-graph MXFP4 permits bitwise here (same scan structure both
    # sides); keep a tight SQNR floor as the cross-platform fallback
    assert bool((out == ref).all()) or _sqnr_db(ref, out) > 60.0


def test_lm_pipeline_parity_cim(lm_setup):
    cfg, params, batch = lm_setup
    cim_cfg = cimlib.CIMConfig()
    batches = [
        {"ids": jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (2, S), 0,
            cfg.vocab_size)}
        for i in range(2)
    ]
    conv, _ = calibrate.convert_model_cim(
        params, cfg, CTX, batches, cim_cfg=cim_cfg, min_n=32
    )
    cctx = dataclasses.replace(CTX, quant="cim", cim=cim_cfg)
    ref, _ = jax.jit(lambda p, b: lm.forward(p, cfg, cctx, b))(conv, batch)
    pipe = pex.build_lm_pipeline(
        conv, cfg, cctx, stages=STAGES, microbatches=2, mb_size=2,
    )
    out = pipe.forward(batch)
    assert _sqnr_db(ref, out) > 60.0  # SQNR-bounded for cim


def test_lm_pipeline_balanced_cuts_parity(lm_setup):
    # imbalanced synthetic costs force unequal layer counts -> the masked
    # padded-scan path; parity must still hold exactly
    cfg, params, batch = lm_setup
    if STAGES < 2:
        pytest.skip("unequal cuts need >= 2 stages")
    ref, _ = jax.jit(lambda p, b: lm.forward(p, cfg, CTX, b))(params, batch)
    pipe = pex.build_lm_pipeline(
        params, cfg, CTX, stages=2, microbatches=2, mb_size=2,
        mode="balanced", costs=[10.0, 1.0, 1.0, 1.0],
    )
    assert pipe.bounds == [(0, 1), (1, 4)]
    assert len(set(pipe.lengths)) > 1
    out = pipe.forward(batch)
    assert bool((out == ref).all())


# ------------------------------------------------------------ ViT parity

@pytest.mark.parametrize("microbatches,mb_size", [(1, 3), (3, 1), (2, 2)])
def test_vit_pipeline_parity_float(vit_setup, microbatches, mb_size):
    cfg, params, batch = vit_setup
    ref, _ = jax.jit(lambda p, b: vit.forward(p, cfg, CTX, b))(params, batch)
    pipe = pex.build_vit_pipeline(
        params, cfg, CTX, stages=STAGES, microbatches=microbatches,
        mb_size=mb_size,
    )
    out = pipe.forward(batch)
    assert out.shape == ref.shape
    assert bool((out == ref).all())


def test_vit_pipeline_parity_mxfp4(vit_setup):
    cfg, params, batch = vit_setup
    qparams = convert_params_mxfp4(params, min_n=32)
    qctx = dataclasses.replace(CTX, quant="mxfp4_wonly")
    ref, _ = jax.jit(lambda p, b: vit.forward(p, cfg, qctx, b))(
        qparams, batch)
    pipe = pex.build_vit_pipeline(
        qparams, cfg, qctx, stages=STAGES, microbatches=2, mb_size=2,
    )
    out = pipe.forward(batch)
    assert bool((out == ref).all()) or _sqnr_db(ref, out) > 60.0


# ------------------------------------------------------- transfer guard

@needs_multidev
def test_transfer_guard_weights_never_move(lm_setup):
    cfg, params, batch = lm_setup
    pipe = pex.build_lm_pipeline(
        params, cfg, CTX, stages=STAGES, microbatches=2, mb_size=2,
    )
    # placed once, resident on the stage axis
    assert pipe.trunk_resident()
    stats = pipe.collectives(batch)
    kinds = set(stats.by_kind)
    assert kinds <= {"collective-permute"}, (
        f"weight-moving collectives in the steady-state step: {kinds}"
    )
    # wire traffic is activation-sized: far below the resident trunk bytes
    assert stats.wire_bytes < pipe.trunk_bytes / 10
    # and running steps does not re-place anything
    pipe.forward(batch)
    pipe.forward(batch)
    assert pipe.trunk_resident()


# ------------------------------------------------------- replica router

def test_replica_router_round_robin(lm_setup):
    cfg, params, batch = lm_setup
    replicas = 2 if N_DEV >= 2 * STAGES else 1
    ref, _ = jax.jit(lambda p, b: lm.forward(p, cfg, CTX, b))(params, batch)
    pipe = pex.build_lm_pipeline(
        params, cfg, CTX, stages=STAGES, replicas=replicas,
        microbatches=2, mb_size=1,
    )
    router = pex.ReplicaRouter(pipe)
    ids = batch["ids"]
    t1 = router.submit({"ids": ids[:2]})
    t2 = router.submit({"ids": ids[2:]})  # ragged: 1 row in a 2-row slot
    t3 = router.submit({"ids": ids[:1]})
    outs = router.flush()
    assert bool((outs[t1] == ref[:2]).all())
    assert bool((outs[t2] == ref[2:]).all())
    assert bool((outs[t3] == ref[:1]).all())
    # round-robin placement: 3 batches over the replica slots in order
    assert sum(router.dispatched) == 3
    if replicas == 2:
        assert router.dispatched == [2, 1]
    assert not router._pending  # drained


# ---------------------------------------------------------- validation

def test_pipeline_capacity_and_model_validation(lm_setup):
    cfg, params, batch = lm_setup
    pipe = pex.build_lm_pipeline(
        params, cfg, CTX, stages=1, microbatches=1, mb_size=2,
    )
    with pytest.raises(ValueError):
        pipe.forward(batch)  # B=3 > capacity 2
    het = dataclasses.replace(cfg, attn_pattern="local_global", lg_ratio=1)
    with pytest.raises(NotImplementedError):
        pex.build_lm_pipeline(params, het, CTX, stages=1)


def test_serve_conversion_args_single_source(lm_setup):
    # the --cim-min-n class of bug: every conversion knob is read from the
    # CLI in exactly one place (conversion_args) and build_backend applies
    # it to every backend — no per-path plumbing left to forget
    import argparse

    from repro.launch import serve as serve_mod

    cfg, params, _ = lm_setup
    args = argparse.Namespace(
        backend="mxfp4", impl="auto", interpret=None, cim_min_n=32,
        adc_bits=10, cm_bits=3, calib_batches=1, batch=2, prompt_len=8,
        log_level="info",
    )
    assert serve_mod.conversion_args(args)["min_n"] == 32
    qparams, ctx = serve_mod.build_backend(args, cfg, params)
    assert ctx.quant == "mxfp4_wonly"
    # min_n=32 actually reached the conversion: the tiny (d=64) linears
    # only pack below the old 256 default
    expect = convert_params_mxfp4(params, min_n=32)
    assert jax.tree.structure(qparams) == jax.tree.structure(expect)
    assert jax.tree.structure(qparams) != jax.tree.structure(params)


def test_serve_pipeline_shape_parsing():
    import argparse

    from repro.launch import serve as serve_mod

    ns = lambda **kw: argparse.Namespace(mesh=None, stages=0, **kw)
    assert serve_mod.pipeline_shape(ns()) is None
    assert serve_mod.pipeline_shape(
        argparse.Namespace(mesh=None, stages=4)) == (1, 4)
    assert serve_mod.pipeline_shape(
        argparse.Namespace(mesh="2x4", stages=0)) == (2, 4)
    with pytest.raises(SystemExit):
        serve_mod.pipeline_shape(argparse.Namespace(mesh="bogus", stages=0))


def test_measured_report_publishes_gauges(lm_setup):
    from repro import obs as obs_mod

    cfg, params, batch = lm_setup
    pipe = pex.build_lm_pipeline(
        params, cfg, CTX, stages=STAGES, microbatches=2, mb_size=2,
    )
    rep = pipe.measure(batch, reps=1)
    assert rep.step_wall_s > 0
    assert len(rep.stage_walls_s) == STAGES
    assert 0.0 <= rep.bubble_fraction <= 1.0
    o = obs_mod.Obs()
    pipe.publish(o.registry)
    snap = o.registry.snapshot()
    assert "pipeline_measured_bubble_fraction" in snap
    assert "pipeline_measured_stage_occupancy" in snap
    walls = snap["pipeline_measured_stage_wall_seconds"]["series"]
    assert {s["labels"]["stage"] for s in walls} == {
        str(i) for i in range(STAGES)
    }
