"""Sharding-rule resolution logic (single-device mesh: pure logic tests;
the 512-device behaviour is exercised by the dry-run sweep)."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.distributed import sharding as shd
from repro.layers.common import ShardingCtx


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_rules_head_shardability(mesh):
    r = shd.make_rules(C.ARCHS["nemotron-4-15b"], mesh, "train")
    assert r["heads"] == "model"  # 48 % 1 == 0 trivially
    r2 = shd.make_rules(C.ARCHS["starcoder2-7b"], mesh, "train")
    assert r2["qkv_fused"] == "model"


def test_decode_rules_flash_decoding(mesh):
    r = shd.make_rules(C.ARCHS["starcoder2-7b"], mesh, "decode",
                       batch_size=128)
    assert r["cache_seq"] == "model"
    assert r["heads"] is None
    r1 = shd.make_rules(C.ARCHS["zamba2-1.2b"], mesh, "decode", batch_size=1)
    assert r1["cache_seq"] == ("data", "model")  # small batch frees `data`


def test_moe_shard_modes(mesh):
    r = shd.make_rules(C.ARCHS["qwen3-moe-235b-a22b"], mesh, "train")
    assert r["experts"] == "model" and r["expert_mlp"] is None  # EP
    r2 = shd.make_rules(C.ARCHS["mixtral-8x22b"], mesh, "train")
    assert r2["experts"] is None and r2["expert_mlp"] == "model"  # TP


def test_resolve_no_axis_reuse(mesh):
    ctx = ShardingCtx(mesh=mesh, rules={"a": "model", "b": "model"})
    spec = ctx.resolve(("a", "b"))
    assert spec[0] == "model" and spec[1] is None  # second use dropped


def test_divisibility_drops_axis(mesh):
    big = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=big, rules={"batch": ("data",), "x": "model"})
    out = shd.resolve_with_divisibility(
        ("batch", "x"), jax.ShapeDtypeStruct((1, 7), jnp.float32), ctx, big
    )
    # both dims divisible by 1 -> kept; logic exercised at 16x16 in dryrun
    assert out.spec[0] in (("data",), "data")


def test_fsdp_param_rules(mesh):
    from repro.launch.steps import param_rules

    r = shd.make_rules(C.ARCHS["h2o-danube-1.8b"], mesh, "train")
    pr = param_rules(r, mesh, fsdp=True)
    assert pr["embed"] == ("data",)
    assert r["embed"] is None  # activation rules untouched


def test_opt_state_zero_specs():
    specs = {"w": ("embed", "mlp"), "g": ("embed",)}
    m = jax.make_mesh((1, 1), ("data", "model"))
    z = shd.opt_state_specs(specs, None, m, zero1=True)
    assert z["w"] == ("zero", "mlp")


def test_skipped_cells_match_design():
    sk = {(a, s) for a, s, _ in C.skipped_cells()}
    assert ("hubert-xlarge", "decode_32k") in sk
    assert ("hubert-xlarge", "long_500k") in sk
    for a in ("starcoder2-7b", "nemotron-4-15b", "qwen3-moe-235b-a22b",
              "qwen2-vl-7b"):
        assert (a, "long_500k") in sk
    assert len(C.all_cells()) == 34
    assert len(sk) == 6


# ----------------------------------------------------- stage partitioning

def test_stage_partition_default_equal_split_unchanged():
    # vit-l32 / bert-large: 24 blocks, 2 chips -> the paper's 12+12 split
    assert shd.stage_partition(24, 2) == [(0, 12), (12, 24)]
    assert shd.stage_partition(7, 3) == [(0, 3), (3, 5), (5, 7)]


def test_stage_partition_balanced_uniform_matches_equal():
    # uniform costs: cost-balancing reduces to the equal split
    assert shd.stage_partition(
        24, 2, mode="balanced", costs=[1.0] * 24
    ) == [(0, 12), (12, 24)]
    # no costs given: balanced falls back to the equal split
    assert shd.stage_partition(24, 2, mode="balanced") == [(0, 12), (12, 24)]


def test_stage_partition_balanced_unequal_counts():
    # one expensive layer pulls the cut: stage 0 takes fewer layers
    costs = [10.0, 1.0, 1.0, 1.0]
    bounds = shd.stage_partition(4, 2, mode="balanced", costs=costs)
    assert bounds == [(0, 1), (1, 4)]
    lens = [hi - lo for lo, hi in bounds]
    assert len(set(lens)) > 1  # genuinely unequal layer counts
    # bottleneck is optimal: no contiguous 2-split beats max(10, 3)
    assert max(sum(costs[lo:hi]) for lo, hi in bounds) == 10.0


def test_stage_partition_balanced_from_blockwise_costs():
    from repro.distributed import blockwise

    cfg = C.ARCHS["starcoder2-7b"]
    costs = blockwise.serve_layer_costs(cfg, 512)
    assert len(costs) == cfg.n_layers
    assert all(c > 0 for c in costs)
    # homogeneous dense trunk: balanced cuts == equal cuts
    assert shd.stage_partition(
        cfg.n_layers, 4, mode="balanced", costs=costs
    ) == shd.stage_partition(cfg.n_layers, 4)


def test_stage_partition_validation():
    with pytest.raises(ValueError):
        shd.stage_partition(4, 5)
    with pytest.raises(ValueError):
        shd.stage_partition(4, 2, mode="weird")
    with pytest.raises(ValueError):
        shd.stage_partition(4, 2, mode="balanced", costs=[1.0, 2.0])
