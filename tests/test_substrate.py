"""Substrate tests: checkpoint atomicity/resume, fault-tolerant trainer
(kill-restart bitwise reproducibility), data pipeline determinism,
optimizer math."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import Pipeline, make_batch
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import lm
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

SHAPE = C.Shape(seq=16, batch=4, kind="train")


def _tiny():
    import dataclasses

    return dataclasses.replace(
        C.tiny(C.ARCHS["xlstm-125m"]), n_layers=2, slstm_at=(1,)
    )


# ----------------------------------------------------------- data pipeline

def test_batch_deterministic_per_step():
    cfg = _tiny()
    b1 = make_batch(cfg, SHAPE, seed=7, step=3)
    b2 = make_batch(cfg, SHAPE, seed=7, step=3)
    b3 = make_batch(cfg, SHAPE, seed=7, step=4)
    np.testing.assert_array_equal(np.asarray(b1["ids"]), np.asarray(b2["ids"]))
    assert not np.array_equal(np.asarray(b1["ids"]), np.asarray(b3["ids"]))


def test_pipeline_prefetch_order():
    cfg = _tiny()
    pipe = Pipeline(cfg, SHAPE, seed=1, start_step=5)
    s0, b0 = pipe.get()
    s1, b1 = pipe.get()
    pipe.close()
    assert (s0, s1) == (5, 6)
    np.testing.assert_array_equal(
        np.asarray(b0["ids"]),
        np.asarray(make_batch(cfg, SHAPE, seed=1, step=5)["ids"]),
    )


# -------------------------------------------------------------- optimizer

def test_adamw_matches_reference():
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0,
                            clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, 2.0]]), "b": jnp.asarray([0.5])}
    g = {"w": jnp.asarray([[0.1, -0.2]]), "b": jnp.asarray([0.3])}
    st = adamw.init(p)
    p2, st2, _ = adamw.apply(cfg, p, g, st)
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/(|g|+eps) = lr*sign(g)
    np.testing.assert_allclose(
        np.asarray(p2["w"]), [[1.0 - 1e-2, 2.0 + 1e-2]], rtol=1e-4
    )
    assert int(st2.step) == 1


def test_grad_clip_applied():
    cfg = adamw.AdamWConfig(lr=0.0, clip_norm=1.0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, st2, met = adamw.apply(cfg, p, g, adamw.init(p))
    assert float(met["grad_norm"]) > 1.0
    # m = (1-b1) * clipped grad; clipped norm == 1
    assert np.linalg.norm(np.asarray(st2.m["w"])) <= (1 - cfg.b1) + 1e-5


def test_schedule_warmup_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.int32(110))) == pytest.approx(0.1)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree), blocking=True)
    assert mgr.latest_step() == 3
    # keep-last-2 GC
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000002", "step_00000003"]
    out = mgr.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]) + 3)


def test_checkpoint_crash_safety(tmp_path):
    """A half-written step dir never corrupts the committed checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((3, 3))}
    mgr.save(1, tree, blocking=True)
    # simulate a crash mid-write: stale tmp dir + LATEST pointing at junk
    os.makedirs(tmp_path / "step_00000002.tmp")
    with open(tmp_path / "step_00000002.tmp" / "leaf_00000.npy", "wb") as f:
        f.write(b"garbage")
    with open(tmp_path / "LATEST", "w") as f:
        f.write("step_00000002")  # committed dir missing
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert mgr2.latest_step() == 1  # falls back to newest valid
    out = mgr2.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((3, 3)))


def test_checkpoint_reshard_on_load(tmp_path):
    """Restore device_puts against a new sharding (elastic restart)."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = {"w": jnp.arange(8.0)}
    mgr.save(5, tree, blocking=True)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = mgr.restore(5, tree, shardings={"w": sh})
    assert out["w"].sharding == sh


# ------------------------------------------------- fault-tolerant trainer

def test_trainer_kill_restart_bitwise(tmp_path):
    cfg = _tiny()
    tc = lambda: TrainerConfig(total_steps=6, ckpt_every=2,
                               ckpt_dir=str(tmp_path / "a"), seed=3)
    # uninterrupted run
    t_full = Trainer(cfg, SHAPE, tc())
    r_full = t_full.run()
    assert r_full["final_step"] == 6
    assert r_full["losses"][0] > r_full["losses"][-1] * 0.5  # sane training

    # interrupted at step 3 (fresh dir), then resumed
    tc2 = TrainerConfig(total_steps=3, ckpt_every=2,
                        ckpt_dir=str(tmp_path / "b"), seed=3)
    Trainer(cfg, SHAPE, tc2).run()
    tc3 = TrainerConfig(total_steps=6, ckpt_every=2,
                        ckpt_dir=str(tmp_path / "b"), seed=3)
    t_resume = Trainer(cfg, SHAPE, tc3)
    assert t_resume.start_step == 3
    t_resume.run()

    flat_a = jax.tree.leaves(t_full.params)
    flat_b = jax.tree.leaves(t_resume.params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_straggler_monitor():
    from repro.runtime.trainer import HeartbeatMonitor

    mon = HeartbeatMonitor(factor=3.0)
    for i in range(10):
        mon.record(i, 0.1)
    mon.record(10, 1.0)  # 10x median
    assert mon.slow_steps and mon.slow_steps[-1][0] == 10
