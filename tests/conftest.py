"""Test-session setup.

Provides a deterministic fallback for ``hypothesis`` when it is not
installed (e.g. a minimal CPU container): the property tests in
``test_mx.py`` / ``test_cim.py`` / ``test_digital.py`` only use
``@given``/``@settings`` with ``st.integers`` and ``st.sampled_from``, so a
tiny seeded sampler preserves their semantics (N pseudo-random examples per
test) without the dependency. With real hypothesis installed (see
``pyproject.toml`` extras; CI installs it) the fallback is inert.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _FALLBACK_MAX_EXAMPLES = 10  # cap: fallback trades coverage for runtime

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _sampled_from(xs) -> _Strategy:
        xs = list(xs)
        return _Strategy(lambda rng: xs[rng.randrange(len(xs))])

    def _given(*strats: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_max_examples", _FALLBACK_MAX_EXAMPLES),
                    _FALLBACK_MAX_EXAMPLES,
                )
                rng = random.Random(0xC1A0)
                for _ in range(n):
                    fn(*args, *[s._draw(rng) for s in strats], **kwargs)

            wrapper.hypothesis_fallback = True
            # hide the drawn parameters from pytest's fixture resolution
            # (real hypothesis does the same)
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco

    def _settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
