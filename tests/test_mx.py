"""MXFP4 numerics: unit + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mx as mxlib

jax.config.update("jax_enable_x64", False)

FP4_VALUES = np.array([0, 0.5, 1, 1.5, 2, 3, 4, 6])
ALL_FP4 = np.concatenate([FP4_VALUES, -FP4_VALUES[1:]])


def test_e2m1_grid_exact():
    """Every representable FP4 value quantizes to itself."""
    codes = mxlib.quantize_e2m1(jnp.asarray(ALL_FP4, jnp.float32))
    np.testing.assert_array_equal(np.asarray(codes), (ALL_FP4 * 2).astype(np.int8))


def test_e2m1_ties_to_even():
    # tie points: 0.25->0 or 0.5? ties-to-even on local grid (0.0 even)
    x = jnp.asarray([0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0], jnp.float32)
    codes = mxlib.quantize_e2m1(x)
    np.testing.assert_array_equal(
        np.asarray(codes), np.array([0, 2, 2, 4, 4, 8, 8], np.int8)
    )


def test_e2m1_clamps_at_6():
    codes = mxlib.quantize_e2m1(jnp.asarray([7.9, -100.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(codes), np.array([12, -12], np.int8))


def test_roundtrip_exact_for_representable():
    """x = fp4 * 2^e round-trips exactly through quantize/dequantize."""
    rng = np.random.default_rng(0)
    e = rng.integers(-20, 20, size=(8, 1))
    vals = rng.choice(ALL_FP4, size=(8, 32))
    # force the max element to 4 or 6 so the block scale is recovered
    vals[:, 0] = 6.0
    x = vals * (2.0**e)
    out = mxlib.dequantize(mxlib.quantize(jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(np.asarray(out), x, rtol=0, atol=0)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_quant_error_bound(seed, rows):
    """|x - Q(x)| <= step/2 where step is the local grid step at scale."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, 32)).astype(np.float32) * 10 ** rng.uniform(-3, 3)
    q = mxlib.quantize(jnp.asarray(x))
    deq = np.asarray(mxlib.dequantize(q))
    scale = 2.0 ** np.asarray(q.exps, np.float32)
    amax = np.abs(x).reshape(rows, 32).max(-1, keepdims=True)
    # max grid step = 2 * scale (top binade); plus scale floor => bound
    err = np.abs(deq - x)
    bound = np.where(np.abs(x) >= 4 * scale, 1.0 * scale, 0.5 * scale) + 1e-7
    # elements in the clamp region (> 6*scale) can err up to amax - 6*scale
    clamp = np.maximum(np.abs(x) - 6 * scale, 0)
    assert np.all(err <= bound + clamp + 1e-6 * amax)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_scale_is_floor_log2_rule(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((4, 64)).astype(np.float32)
    q = mxlib.quantize(jnp.asarray(x))
    amax = np.abs(x).reshape(4, 2, 32).max(-1)
    expect = np.floor(np.log2(amax)) - mxlib.EMAX_ELEM
    np.testing.assert_array_equal(np.asarray(q.exps, np.float64), expect)


def test_zero_block():
    q = mxlib.quantize(jnp.zeros((2, 32)))
    assert np.all(np.asarray(q.codes) == 0)
    out = mxlib.dequantize(q)
    assert np.all(np.asarray(out) == 0)


def test_padding_non_multiple_of_32():
    x = np.random.default_rng(1).standard_normal((3, 80)).astype(np.float32)
    q = mxlib.quantize(jnp.asarray(x))
    assert q.codes.shape == (3, 96) and q.exps.shape == (3, 3)
    out = mxlib.dequantize(q, out_len=80)
    assert out.shape == (3, 80)
    # padded tail quantizes to zero codes
    assert np.all(np.asarray(q.codes)[:, 80:] == 0)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, 64)).astype(np.float32)
    q = mxlib.quantize(jnp.asarray(x))
    packed = mxlib.pack_codes(q.codes)
    assert packed.shape == (2, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(mxlib.unpack_codes(packed)), np.asarray(q.codes)
    )


def test_unsigned_weight_encoding_roundtrip():
    x = np.random.default_rng(2).standard_normal((4, 32)).astype(np.float32)
    q = mxlib.quantize(jnp.asarray(x))
    u = mxlib.encode_weight_unsigned(q)
    assert u.dtype == jnp.uint8
    assert np.all(np.asarray(u) >= 0) and np.all(np.asarray(u) <= 24)
    np.testing.assert_array_equal(
        np.asarray(mxlib.decode_weight_unsigned(u)), np.asarray(q.codes)
    )


def test_exps_biased_roundtrip():
    e = jnp.asarray([-127, -1, 0, 5, 127], jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(mxlib.exps_from_biased(mxlib.exps_to_biased(e))), np.asarray(e)
    )


def test_fake_quant_ste_gradient():
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 32)), jnp.float32)
    g = jax.grad(lambda t: jnp.sum(mxlib.fake_quant(t) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones_like(x), rtol=0)


def test_fake_quant_matches_quant_dequant():
    x = jnp.asarray(np.random.default_rng(4).standard_normal((2, 80)), jnp.float32)
    fq = mxlib.fake_quant(x)
    qd = mxlib.dequantize(mxlib.quantize(x), out_len=80)
    np.testing.assert_array_equal(np.asarray(fq), np.asarray(qd))
    assert fq.shape == x.shape


def test_quantize_w_layout():
    w = np.random.default_rng(5).standard_normal((64, 16)).astype(np.float32)
    wq = mxlib.quantize_w(jnp.asarray(w))
    assert wq.codes.shape == (64, 16) and wq.exps.shape == (2, 16)
    deq = np.asarray(mxlib.dequantize_w(wq))
    # block structure: scale shared along K per column
    err = np.abs(deq - w)
    assert err.max() < np.abs(w).max()  # sanity: quantization not garbage
    # exactness for representable values
    w2 = np.zeros((32, 2), np.float32)
    w2[:, 0] = 6.0
    w2[:, 1] = 3.0
    np.testing.assert_array_equal(
        np.asarray(mxlib.dequantize_w(mxlib.quantize_w(jnp.asarray(w2)))), w2
    )


def test_mx_dot_bf16_close_to_fp32():
    rng = np.random.default_rng(6)
    a = rng.standard_normal((4, 64)).astype(np.float32)
    b = rng.standard_normal((64, 8)).astype(np.float32)
    am, bm = mxlib.quantize(jnp.asarray(a)), mxlib.quantize_w(jnp.asarray(b))
    ref = np.asarray(mxlib.dequantize(am, out_len=64)) @ np.asarray(
        mxlib.dequantize_w(bm)
    )
    out = np.asarray(mxlib.mx_dot_bf16(am, bm), np.float32)
    out2 = np.asarray(mxlib.mx_dot_bf16(am, bm, bf16_partials=True), np.float32)
    np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(out2, ref, rtol=4e-2, atol=4e-2)
