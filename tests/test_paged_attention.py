"""Ragged paged flash-decode invariants (kernels/paged_attention).

Equivalence ladder, strongest first:

- *Layout*: the fused head-interleaved page mirrors (raw and MXFP4
  quantized-resident) decode **bitwise** to the PR 4 legacy split
  mirrors, and the per-step resident update is bitwise what a full
  requant of the updated pages would produce.
- *Reference*: the jnp ragged paged reference is **bitwise** the legacy
  decode-branch math from ``layers.attention.attn_apply`` on every
  legacy-reachable input, float and quantized-resident alike.
- *Kernel*: the Pallas streaming kernel (interpret mode on CPU) matches
  the reference to tolerance — it re-quantizes P per KV chunk, the same
  dense-vs-flash granularity precedent as ``_flash_attn``.
- *Model*: ``lm.decode_step`` over a fused cache is **bitwise** the
  legacy-cache decode, logits included; the serving engine produces
  identical tokens under either pool layout.

Ragged coverage: every lane at a different cache length, including 0
(parked lane), 1, exact 32-block boundaries, ring wrap (length == W),
and a page width that is not a multiple of the chunk (W=48, bk=32 —
clamped tail fetch with masked overlap).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs as C
from repro.core import mx as mxlib
from repro.core.metrics import sqnr_db
from repro.kernels.paged_attention import layout, ops
from repro.kernels.paged_attention import ref as pref
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import lm

P, W, HKV, G, DH = 5, 48, 2, 3, 32
L = 4
SCALE = DH**-0.5


def _pages(seed: int, p=P, w=W):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = (jax.random.normal(ks[0], (p, w, HKV, DH)) * 0.7).astype(jnp.bfloat16)
    v = (jax.random.normal(ks[1], (p, w, HKV, DH)) * 0.7).astype(jnp.bfloat16)
    q = (jax.random.normal(ks[2], (L, HKV, G, DH)) * 0.7).astype(jnp.bfloat16)
    return k, v, q


def _ragged(seed: int, w=W):
    """Ragged lane lengths biased toward the edge cases: 0 (parked), 1,
    32-block boundaries, the partial trailing block, and full/wrapped."""
    rs = np.random.RandomState(seed)
    edge = [0, 1, mxlib.BLOCK - 1, mxlib.BLOCK, mxlib.BLOCK + 1, w - 1, w]
    lens = [int(rs.choice(edge)) if rs.rand() < 0.5
            else int(rs.randint(0, w + 1)) for _ in range(L)]
    rows = rs.permutation(P)[:L].astype(np.int32)
    return jnp.asarray(rows), jnp.asarray(lens, jnp.int32)


# --------------------------------------------------------------- layout

def test_fuse_split_roundtrip():
    k, v, _ = _pages(0)
    kv = layout.fuse_kv(k, v)
    assert kv.shape == (P, W, 2 * HKV, DH)
    k2, v2 = layout.split_kv(kv)
    np.testing.assert_array_equal(np.asarray(k2, np.float32),
                                  np.asarray(k, np.float32))
    np.testing.assert_array_equal(np.asarray(v2, np.float32),
                                  np.asarray(v, np.float32))


def test_fused_mirrors_decode_bitwise_to_legacy():
    """quant_page_full runs the same quantize calls as the legacy mirror
    fill; nibble packing is lossless, so dequant is bitwise equal."""
    k, v, _ = _pages(1)
    quant = layout.quant_page_full(k, v)
    kd = layout.dequant_k_pages(quant["kv_codes"], quant["k_exps"], DH)
    leg_k = mxlib.dequantize(
        mxlib.quantize(k.astype(jnp.float32)), out_len=DH
    ).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(kd, np.float32),
                                  np.asarray(leg_k, np.float32))
    vd = layout.dequant_v_pages(quant["kv_codes"], quant["v_exps"], DH)
    leg_v = jnp.moveaxis(
        mxlib.dequantize(mxlib.quantize_axis(v.astype(jnp.float32), 1),
                         out_len=W), -1, 1,
    ).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(vd, np.float32),
                                  np.asarray(leg_v, np.float32))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quant_page_step_bitwise_full_requant(seed):
    """The O(1)-per-token resident mirror update (written K row + active
    V 32-block only) is bitwise what requantizing the whole updated pool
    would produce — including at partial-trailing-block slots (W=48)."""
    rs = np.random.RandomState(seed)
    k, v, _ = _pages(2)
    kv = layout.fuse_kv(k, v)
    quant = layout.quant_page_full(k, v)
    rows = jnp.asarray(rs.permutation(P)[:L].astype(np.int32))
    slot = jnp.asarray(rs.randint(0, W, size=L).astype(np.int32))
    knew = (jax.random.normal(jax.random.PRNGKey(seed), (L, HKV, DH))
            ).astype(jnp.bfloat16)
    vnew = jnp.roll(knew, 1, axis=-1)
    kv2 = kv.at[rows, slot].set(layout.fuse_kv(knew, vnew))
    got = layout.quant_page_step(quant, kv2, rows, slot)
    k2, v2 = layout.split_kv(kv2)
    want = layout.quant_page_full(k2, v2)
    for name in ("kv_codes", "k_exps", "v_exps"):
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]), err_msg=name)


# ------------------------------------------------------------ reference

def _legacy_float(q, kd, vd, lens, scale=SCALE):
    """The PR 4 decode-branch math, inlined (same einsums/op order)."""
    w = kd.shape[1]
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", q[:, None], kd,
                    preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(w)[None, :] < lens[:, None]
    sc = jnp.where(valid[:, None, None, None, :], sc, -jnp.inf)
    pr = jax.nn.softmax(sc, axis=-1)
    pr = jnp.where(valid.any(-1)[:, None, None, None, None], pr, 0.0)
    return jnp.einsum("bhgqk,bkhd->bqhgd", pr.astype(vd.dtype), vd)[:, 0]


def _legacy_mx(q, kd, vd, lens, scale=SCALE):
    w = kd.shape[1]
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", q[:, None], kd,
                    preferred_element_type=jnp.float32) * scale
    sc = sc.astype(jnp.bfloat16).astype(jnp.float32)
    valid = jnp.arange(w)[None, :] < lens[:, None]
    sc = jnp.where(valid[:, None, None, None, :], sc, -jnp.inf)
    pr = jax.nn.softmax(sc, axis=-1)
    pr = jnp.where(valid.any(-1)[:, None, None, None, None], pr, 0.0)
    pr = mxlib.fake_quant(pr)
    den = jnp.sum(pr, axis=-1, keepdims=True)
    den = jnp.where(den == 0.0, 1.0, den)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr.astype(jnp.bfloat16),
                   vd.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    return (o / jnp.moveaxis(den, -2, 1)).astype(jnp.bfloat16)[:, 0]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_float_ref_bitwise_legacy_math(seed):
    k, v, q = _pages(3)
    rows, lens = _ragged(seed)
    ref = pref.ragged_paged_decode_ref(
        q, rows, lens, kv=layout.fuse_kv(k, v), scale=SCALE
    )
    leg = _legacy_float(q, k[rows], v[rows], lens)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(leg, np.float32))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_mx_ref_bitwise_legacy_math(seed):
    """Quantized-resident path: ref over the fused code mirrors is
    bitwise the legacy requant-per-step decode math."""
    k, v, q = _pages(4)
    rows, lens = _ragged(seed)
    quant = layout.quant_page_full(k, v)
    qmx = mxlib.fake_quant(q).astype(jnp.bfloat16)
    ref = pref.ragged_paged_decode_ref(qmx, rows, lens, quant=quant,
                                       scale=SCALE)
    kd = mxlib.dequantize(mxlib.quantize(k.astype(jnp.float32)),
                          out_len=DH).astype(jnp.bfloat16)
    vd = jnp.moveaxis(
        mxlib.dequantize(mxlib.quantize_axis(v.astype(jnp.float32), 1),
                         out_len=W), -1, 1,
    ).astype(jnp.bfloat16)
    leg = _legacy_mx(qmx, kd[rows], vd[rows], lens)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(leg, np.float32))


# --------------------------------------------------------------- kernel

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_float_kernel_matches_ref(seed):
    """Streaming kernel vs dense reference across ragged lengths —
    W=48 with bk=32 exercises the clamped tail fetch every run."""
    k, v, q = _pages(5)
    rows, lens = _ragged(seed)
    kv = layout.fuse_kv(k, v)
    ref = pref.ragged_paged_decode_ref(q, rows, lens, kv=kv, scale=SCALE)
    got = ops.ragged_paged_decode(q, rows, lens, kv=kv, scale=SCALE,
                                  use_pallas=True, interpret=True,
                                  bk=32, buffers=2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.04, rtol=0.05)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_mx_kernel_matches_ref(seed):
    """Quantized-resident kernel: in-tile pair-table dequant + per-chunk
    P quantization vs the whole-key-axis reference."""
    k, v, q = _pages(6)
    rows, lens = _ragged(seed)
    quant = layout.quant_page_full(k, v)
    qmx = mxlib.fake_quant(q).astype(jnp.bfloat16)
    ref = pref.ragged_paged_decode_ref(qmx, rows, lens, quant=quant,
                                       scale=SCALE)
    got = ops.ragged_paged_decode(qmx, rows, lens, quant=quant, scale=SCALE,
                                  use_pallas=True, interpret=True,
                                  bk=32, buffers=2)
    # per-chunk vs whole-axis P quantization: individual elements can
    # move by a P code flip, so the bound is distributional (the repo's
    # dense-vs-flash precedent, cf. test_backends sqnr checks) plus a
    # hard cap on any single element
    ref32, got32 = np.asarray(ref, np.float32), np.asarray(got, np.float32)
    live = np.asarray(lens) > 0
    if live.any():
        # measured 16-26 dB across seeds on random-init (near-uniform
        # softmax — every key's P code flip is visible); real activations
        # concentrate attention and land far higher
        assert sqnr_db(ref32[live], got32[live]) > 13.0
    np.testing.assert_allclose(got32, ref32, atol=0.35, rtol=0.0)
    np.testing.assert_array_equal(got32[~live], 0.0)


def test_kernel_ragged_extremes():
    """Pinned worst cases: parked lane (0), single token, 32-boundary
    straddle, partial trailing block, full/wrapped page."""
    k, v, q = _pages(7)
    kv = layout.fuse_kv(k, v)
    rows = jnp.asarray([4, 0, 2, 1], jnp.int32)
    for lens in ([0, 1, 32, 48], [33, 47, 31, 0], [48, 48, 1, 17]):
        lens = jnp.asarray(lens, jnp.int32)
        ref = pref.ragged_paged_decode_ref(q, rows, lens, kv=kv, scale=SCALE)
        got = ops.ragged_paged_decode(q, rows, lens, kv=kv, scale=SCALE,
                                      use_pallas=True, interpret=True,
                                      bk=32, buffers=2)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=0.04, rtol=0.05)
        # a zero-length lane must come out exactly zero
        zero = np.flatnonzero(np.asarray(lens) == 0)
        np.testing.assert_array_equal(
            np.asarray(got, np.float32)[zero], 0.0
        )


def test_kernel_long_page_quad_buffered():
    """Auto knobs on a long page: bk=128, quad buffering, many chunks."""
    w = 1024
    k, v, _ = _pages(8, p=2, w=w)
    q = _pages(8)[2][:2]
    kv = layout.fuse_kv(k, v)
    rows = jnp.asarray([1, 0], jnp.int32)
    lens = jnp.asarray([1024, 700], jnp.int32)
    assert ops.pick_bk(w) == 128 and ops.pick_buffers(w, 128) == 4
    ref = pref.ragged_paged_decode_ref(q, rows, lens, kv=kv, scale=SCALE)
    got = ops.ragged_paged_decode(q, rows, lens, kv=kv, scale=SCALE,
                                  use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.04, rtol=0.05)


def test_ops_rejects_ambiguous_operands():
    k, v, q = _pages(9)
    rows = jnp.zeros((L,), jnp.int32)
    lens = jnp.ones((L,), jnp.int32)
    with pytest.raises(ValueError, match="exactly one"):
        ops.ragged_paged_decode(q, rows, lens, scale=SCALE)
    with pytest.raises(ValueError, match="exactly one"):
        ops.ragged_paged_decode(q, rows, lens, kv=layout.fuse_kv(k, v),
                                quant=layout.quant_page_full(k, v),
                                scale=SCALE)


# ---------------------------------------------------------- model level

CFG = C.tiny(C.ARCHS["starcoder2-7b"])


@pytest.fixture(scope="module")
def model():
    params, _ = lm.init_model(jax.random.PRNGKey(0), CFG)
    return params, RunCtx(shd=ShardingCtx(), dense_attn_max=256)


@pytest.mark.parametrize("quant", ["none", "mxfp4_digital"])
def test_model_decode_fused_bitwise_legacy(model, quant):
    """lm.decode_step over a fused paged cache == legacy cache, bitwise
    logits, prefill-into-cache and several decode steps deep — on the
    float path and the quantized-resident digital-SDPA path."""
    params, ctx = model
    if quant != "none":
        params = convert_params_mxfp4(params)
        ctx = dataclasses.replace(ctx, quant=quant)
    mx_dig = ctx.hybrid_digital_sdpa
    t, pre, page = 10, 4, 16
    ids = jax.random.randint(jax.random.PRNGKey(5), (1, t), 0,
                             CFG.vocab_size)
    legacy = lm.init_cache(CFG, 1, page, mx_digital=mx_dig)
    fused = lm.init_cache(CFG, 1, page, mx_digital=mx_dig, fused=True)
    _, legacy = lm.forward(params, CFG, ctx, {"ids": ids[:, :pre]},
                           caches=legacy)
    _, fused = lm.forward(params, CFG, ctx, {"ids": ids[:, :pre]},
                          caches=fused)
    for p in range(pre, t):
        lg_l, legacy = lm.decode_step(params, CFG, ctx, ids[:, p:p + 1],
                                      jnp.int32(p), legacy)
        lg_f, fused = lm.decode_step(params, CFG, ctx, ids[:, p:p + 1],
                                     jnp.int32(p), fused)
        np.testing.assert_array_equal(
            np.asarray(lg_f, np.float32), np.asarray(lg_l, np.float32),
            err_msg=f"fused decode diverged at pos {p} ({quant})",
        )


def test_fused_engine_matches_legacy_engine(model):
    """Continuous-batching engine, quantized-resident pool: the fused
    in-place paged decode (RunCtx.paged_rows, no gather/scatter) emits
    identical tokens to the legacy gather->decode->scatter engine."""
    from repro.serving import Engine, EngineConfig

    params, ctx = model
    params = convert_params_mxfp4(params)
    ctx = dataclasses.replace(ctx, quant="mxfp4_digital")
    rng = np.random.default_rng(11)
    reqs = [
        (rng.integers(0, CFG.vocab_size, size=rng.integers(2, 8)).tolist(),
         int(rng.integers(2, 6)))
        for _ in range(4)
    ]

    def run(layout_name):
        ecfg = EngineConfig(lanes=3, num_slots=4, page_len=24,
                            prefill_len=8, kv_layout=layout_name)
        eng = Engine(params, CFG, ctx, ecfg)
        for prompt, max_new in reqs:
            eng.add_request(prompt, max_new=max_new)
            eng.step()
        return eng.run()

    assert run("fused") == run("legacy")


def test_fused_mx_cache_requires_resident_mirrors(model):
    """A fused cache without code mirrors under a digital-SDPA backend
    is a configuration error, not a silent fallback."""
    params, ctx = model
    params = convert_params_mxfp4(params)
    ctx = dataclasses.replace(ctx, quant="mxfp4_digital")
    ids = jax.random.randint(jax.random.PRNGKey(6), (1, 4), 0,
                             CFG.vocab_size)
    cache = lm.init_cache(CFG, 1, 16, fused=True)  # no mirrors
    _, cache = lm.forward(params, CFG, ctx, {"ids": ids}, caches=cache)
    with pytest.raises(ValueError, match="quantized-resident"):
        lm.decode_step(params, CFG, ctx, ids[:, -1:], jnp.int32(4), cache)
