"""Layer-level invariants: RoPE properties, MoE routing semantics,
Mamba2 chunked == single-chunk, serving conversion density."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.layers import rope, ssm
from repro.layers.common import (
    RunCtx,
    ShardingCtx,
    convert_params_mxfp4,
    quantize_weights_tree,
)
from repro.models import lm

CTX = RunCtx(shd=ShardingCtx())


# ------------------------------------------------------------------ RoPE

def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = rope.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )


def test_rope_relative_position_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = 16
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))

    def dot_at(i, j):
        qr = rope.apply_rope(q, jnp.array([[i]]))
        kr = rope.apply_rope(k, jnp.array([[j]]))
        return float(jnp.sum(qr * kr))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(7, 7), rel=1e-4)


def test_mrope_text_equals_rope_when_sections_align():
    """With all three position components equal, M-RoPE is a valid RoPE
    (norm-preserving, relative-position property)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, 1, 32))
    pos = jnp.arange(6)[None]
    y = rope.apply_mrope(x, rope.text_mrope_positions(pos), sections=(4, 6, 6))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )


# ------------------------------------------------------------------- MoE

def _moe_setup(t=64, d=32, e=4, top_k=2):
    from repro.layers import moe as moe_mod

    p, _ = moe_mod.moe_init(jax.random.PRNGKey(0), d, 48, e, "swiglu",
                            "rmsnorm")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, t, d), jnp.bfloat16)
    return moe_mod, p, x


def test_moe_residual_and_finite():
    moe_mod, p, x = _moe_setup()
    y = moe_mod.moe_apply(CTX, "swiglu", "rmsnorm", p, x, top_k=2)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    # residual: zero expert weights => y == x
    p0 = dict(p)
    p0["w2"] = jnp.zeros_like(p["w2"])
    y0 = moe_mod.moe_apply(CTX, "swiglu", "rmsnorm", p0, x, top_k=2)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(x, np.float32), rtol=1e-2)


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor most tokens are dropped => output closer
    to the residual than with generous capacity."""
    moe_mod, p, x = _moe_setup(t=128)
    y_full = moe_mod.moe_apply(CTX, "swiglu", "rmsnorm", p, x, top_k=2,
                               capacity_factor=4.0)
    y_tiny = moe_mod.moe_apply(CTX, "swiglu", "rmsnorm", p, x, top_k=2,
                               capacity_factor=0.05)
    d_full = float(jnp.linalg.norm((y_full - x).astype(jnp.float32)))
    d_tiny = float(jnp.linalg.norm((y_tiny - x).astype(jnp.float32)))
    assert d_tiny < d_full


def test_moe_group_count_invariance():
    """Dispatch grouping must not change results (same capacity slack)."""
    from repro.layers import moe as moe_mod

    p, _ = moe_mod.moe_init(jax.random.PRNGKey(0), 32, 48, 4, "gelu",
                            "rmsnorm")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32), jnp.bfloat16)
    y1 = moe_mod.moe_apply(CTX, "gelu", "rmsnorm", p, x, top_k=1,
                           capacity_factor=8.0)
    # monkeypatch group count
    orig = moe_mod._n_groups
    moe_mod._n_groups = lambda ctx, t: 4
    try:
        y4 = moe_mod.moe_apply(CTX, "gelu", "rmsnorm", p, x, top_k=1,
                               capacity_factor=8.0)
    finally:
        moe_mod._n_groups = orig
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y4, np.float32), rtol=2e-2,
                               atol=2e-2)


# ----------------------------------------------------------------- Mamba2

def test_ssd_chunk_size_invariance():
    b, s, h, pdim, n = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (b, s, h, pdim))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    bm = jax.random.normal(ks[3], (b, s, 1, n))
    cm = jax.random.normal(ks[0], (b, s, 1, n))
    y1, s1 = ssm._ssd_chunked(x, dt, a, bm, cm, chunk=8)
    y2, s2 = ssm._ssd_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-5)


# -------------------------------------------------- serving conversion

def test_convert_packs_stacked_weights():
    """Layer-stacked (3-D/4-D) weights must be packed too — resident
    density ~4.25 bits/param (the FWS storage claim)."""
    cfg = C.tiny(C.ARCHS["mixtral-8x22b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    conv = convert_params_mxfp4(params, min_n=32)  # tiny dims
    nbytes = sum(x.nbytes for x in jax.tree.leaves(conv))
    seg = conv["segments"][0]
    assert "codes" in seg["moe"]["w1"], "stacked expert weights not packed"
    assert "codes" in seg["attn"]["wq"], "stacked linear weights not packed"
    # embedding + norms stay unpacked; overall well under bf16 density
    assert nbytes < 1.2 * n_params  # < ~9.6 bits/param incl. embeddings


def test_prequant_tree_is_exact_hoisting():
    """quantize_weights_tree == per-use fake-quant (weights const/step)."""
    cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    qt = quantize_weights_tree(params)
    w = params["segments"][0]["attn"]["wq"]["w"]  # [L, K, N]
    from repro.core import mx as mxlib

    per_use = mxlib.fake_quant_axis(w[0], axis=0).astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(qt["segments"][0]["attn"]["wq"]["w"][0], np.float32),
        np.asarray(per_use, np.float32),
    )
