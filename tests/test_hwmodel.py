"""Analytical hardware model vs the paper's published numbers."""

import pytest

from repro.hwmodel import perf, specs as S


def rel(a, b):
    return abs(a - b) / abs(b)


def test_macro_tops_table3():
    assert rel(perf.macro_tops(768), 20.02) < 0.02
    assert rel(perf.macro_tops(1024), 35.72) < 0.02


def test_macro_storage_density():
    # §6: 1024x1024 CTT arrays reach ~1756 kb/mm^2
    assert rel(perf.storage_density_kb_mm2(1024), 1756) < 0.02
    # §6 claim: >= 50x the TSMC gain-cell macro (~34 kb/mm^2)
    assert perf.storage_density_kb_mm2(1024) / 34 > 50


def test_system_area_table4():
    assert rel(perf.system_area_mm2(S.BASE), 376.3) < 0.005
    assert rel(perf.system_area_mm2(S.LARGE), 561.5) < 0.005


def test_system_peak_tops_table4():
    assert rel(perf.system_peak_tops(S.BASE), 1515.14) < 0.03
    assert rel(perf.system_peak_tops(S.LARGE), 2631.56) < 0.03


def test_system_power_table4():
    t4 = perf.table4()
    assert rel(t4["base"]["power_w"], 163.16) < 0.05
    # Large peak-point utilization model deviates ~8% (documented)
    assert rel(t4["large"]["power_w"], 182.61) < 0.10


def test_n_balance():
    # paper: TOPS peaks at N=256 (Base) / N=192 (Large), approximate
    assert 200 <= perf.n_balance(S.BASE) <= 320
    assert 150 <= perf.n_balance(S.LARGE) <= 240


@pytest.mark.parametrize("name", sorted(S.PAPER_TABLE7))
def test_table7_fps(name):
    w = S.WORKLOADS[name]
    paper_fps = S.PAPER_TABLE7[name][1]
    assert rel(perf.fps(w), paper_fps) < 0.05, (perf.fps(w), paper_fps)


@pytest.mark.parametrize("name", sorted(S.PAPER_TABLE7))
def test_table7_tops(name):
    w = S.WORKLOADS[name]
    paper_tops = S.PAPER_TABLE7[name][2]
    assert rel(perf.tops(w) * w.chips / w.chips, paper_tops) < 0.08


@pytest.mark.parametrize("name", sorted(S.PAPER_TABLE7))
def test_table7_power(name):
    w = S.WORKLOADS[name]
    paper_w = S.PAPER_TABLE7[name][0]
    assert rel(perf.model_power_w(w), paper_w) < 0.20  # documented tolerance


@pytest.mark.parametrize("name", sorted(S.PAPER_TABLE9))
def test_table9_fps(name):
    """fps-only SOTA rows (Table 9): deit-b16 shares vit-b16 geometry and
    must land on the paper's 41,269 img/s like the Table 7 sweep."""
    w = S.WORKLOADS[name]
    assert rel(perf.fps(w), S.PAPER_TABLE9[name]) < 0.05
    # table7() exposes it alongside the Table 7 rows
    assert rel(perf.table7()[name]["fps"], S.PAPER_TABLE9[name]) < 0.05


def test_deit_b16_coincides_with_vit_b16():
    """Why deit-b16 has no separate Table 1/7 rows: identical (N, d,
    layers, params) make every derived figure coincide with vit-b16's."""
    deit, vitb = S.WORKLOADS["deit-b16"], S.WORKLOADS["vit-b16"]
    assert (deit.seq, deit.d, deit.layers, deit.params_m) == (
        vitb.seq, vitb.d, vitb.layers, vitb.params_m)
    assert perf.fps(deit) == perf.fps(vitb)
    assert perf.io_penalty(deit) == perf.io_penalty(vitb)


@pytest.mark.parametrize("name", sorted(S.PAPER_TABLE1))
def test_table1_io_penalty(name):
    """Pin the paper's five reported (penalty_max_batch, max_batch,
    penalty_b1) rows, tolerance-bounded, plus the structural relations
    the derivation implies."""
    w = S.WORKLOADS[name]
    pm, bm, p1 = perf.io_penalty(w)
    paper_pm, paper_bm, paper_p1 = S.PAPER_TABLE1[name]
    assert rel(pm, paper_pm) < 0.05
    assert rel(bm, paper_bm) < 0.05
    assert rel(p1, paper_p1) < 0.05
    # penalty decreases with batch (weights amortize) and B* >= 1
    assert p1 > pm > 1.0
    assert isinstance(bm, int) and bm >= 1


def test_fig12_shape():
    rows = perf.fig12_sweep()
    # analog-bound below balance, digital-bound above; TOPS peaks near N_bal
    tops = [r["tops"] for r in rows]
    peak_n = rows[tops.index(max(tops))]["N"]
    assert 128 <= peak_n <= 320
    # TOPS rises then falls
    assert tops[0] < max(tops) and tops[-1] < max(tops)


def test_2pass_halves_analog_throughput():
    assert rel(perf.analog_tops(S.BASE, passes=1),
               2 * perf.analog_tops(S.BASE, passes=2)) < 1e-9


def test_ctt_density_advantage_table2():
    # CTT >= 1.5x denser than ReRAM/PCM/FeRAM per stored bit
    ctt = S.NVM["ctt"]["cell_f2"] / S.NVM["ctt"]["max_bits"]
    for other in ("reram", "pcm", "feram"):
        o = S.NVM[other]["cell_f2"] / S.NVM[other]["max_bits"]
        assert o / ctt >= 1.5
