"""Linear-execution backend registry + end-to-end hybrid CIM path.

The crux parity facts (measured, with margin):

- with a *lossless* CIM config (no ADC, unbounded CM window) the hybrid
  ``cim_analog`` model forward is numerically identical to the fully
  digital MXFP4 model (``mxfp4_digital``): the analog wiring is exactly
  the paper's digital composition, so any deviation at the paper operating
  point is attributable to the modelled ADC + current-mirror effects;
- per linear, the backend forward matches ``core/cim.py``'s
  ``cim_linear`` reference composition bit-for-bit;
- at the paper operating point (10b ADC, CM=3, 2-pass) the tiny-model
  logit deviation stays bounded (the <1% accuracy-preservation claim,
  scaled to this smoke setup: random-init logits are near-uniform, a
  worst case for top-1 agreement).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core import cim as cimlib
from repro.core import mx as mxlib
from repro.core.metrics import sqnr_db as _sqnr_db
from repro.layers import backends
from repro.layers.common import RunCtx, ShardingCtx, linear_apply, linear_init
from repro.models import calibrate, lm

CTX = RunCtx(shd=ShardingCtx(), dense_attn_max=256)


# ---------------------------------------------------------------- registry

def test_registry_names_and_aliases():
    assert backends.backend_names() == [
        "cim_analog", "float_bf16", "mxfp4_ste", "mxfp4_ste_prequant",
        "mxfp4_wonly",
    ]
    assert backends.get_backend("none").name == "float_bf16"
    assert backends.get_backend("cim").name == "cim_analog"
    assert backends.get_backend("mxfp4_digital").name == "mxfp4_ste"


def test_unknown_backend_raises():
    p, _ = linear_init(jax.random.PRNGKey(0), 64, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    bad = dataclasses.replace(CTX, quant="int8_heresy")
    with pytest.raises(ValueError, match="unknown linear-execution backend"):
        linear_apply(bad, p, x)
    with pytest.raises(ValueError):
        backends.expert_weight(bad, jnp.zeros((2, 64, 64)))


def test_converted_param_markers_win_over_ctx_quant():
    """Serving trees dispatch by what is resident, not by context string."""
    p, _ = linear_init(jax.random.PRNGKey(0), 64, 256)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    packed = backends.quantize_linear_params(p)
    assert backends.resolve_backend(CTX, packed).name == "mxfp4_wonly"
    wq = mxlib.quantize_w(p["w"])
    cfg = cimlib.CIMConfig()
    cal = cimlib.calibrate_rowhist([x], wq, cfg)
    cim_node = backends.get_backend("cim").convert(p, cal)
    assert backends.resolve_backend(CTX, cim_node).name == "cim_analog"
    # and both still execute under a float ctx
    assert linear_apply(CTX, packed, x).shape == (4, 256)
    assert linear_apply(CTX, cim_node, x).shape == (4, 256)


def test_backward_compatible_quant_modes_match_legacy_numerics():
    p, _ = linear_init(jax.random.PRNGKey(0), 64, 96)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    y_none = linear_apply(CTX, p, x)
    np.testing.assert_array_equal(
        np.asarray(y_none, np.float32),
        np.asarray(
            jnp.matmul(x.astype(jnp.bfloat16), p["w"].astype(jnp.bfloat16)),
            np.float32,
        ),
    )
    ste = dataclasses.replace(CTX, quant="mxfp4_ste")
    wq = mxlib.fake_quant_axis(p["w"], axis=0).astype(jnp.bfloat16)
    xq = mxlib.fake_quant(x.astype(jnp.float32)).astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(linear_apply(ste, p, x), np.float32),
        np.asarray(jnp.matmul(xq, wq), np.float32),
    )


# ------------------------------------------------------- cim node numerics

def test_cim_backend_matches_core_reference_exactly():
    """backend forward == core/cim.py reference composition, bit for bit."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (8, 96), jnp.float32)
    p, _ = linear_init(jax.random.fold_in(key, 1), 96, 48)
    cfg = cimlib.CIMConfig()
    wq = mxlib.quantize_w(p["w"])
    cal = cimlib.calibrate_rowhist([x], wq, cfg)
    node = backends.get_backend("cim").convert(p, cal)
    np.testing.assert_array_equal(np.asarray(node["codes"]), np.asarray(wq.codes))
    ctx = dataclasses.replace(CTX, quant="cim", cim=cfg)
    y = linear_apply(ctx, node, x)
    ref, _ = cimlib.cim_linear(x, wq, cfg, cal)
    np.testing.assert_array_equal(
        np.asarray(y, np.float32),
        np.asarray(ref.astype(jnp.bfloat16), np.float32),
    )


def test_cim_backend_pallas_matches_jnp():
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (16, 64), jnp.float32)
    p, _ = linear_init(jax.random.fold_in(key, 1), 64, 32)
    cfg = cimlib.CIMConfig()
    wq = mxlib.quantize_w(p["w"])
    cal = cimlib.calibrate_rowhist([x], wq, cfg)
    node = backends.get_backend("cim").convert(p, cal)
    jnp_ctx = dataclasses.replace(CTX, quant="cim", cim=cfg)
    pls_ctx = dataclasses.replace(jnp_ctx, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(linear_apply(pls_ctx, node, x), np.float32),
        np.asarray(linear_apply(jnp_ctx, node, x), np.float32),
        rtol=1e-2, atol=1e-2,  # bf16 cast after the f32 kernel output
    )


def test_interpret_flag_threads_into_kernels(monkeypatch):
    """RunCtx.interpret reaches both Pallas kernel wrappers (no hardcoded
    interpret=True left at the callsites)."""
    from repro.kernels.cim_linear import ops as cim_ops
    from repro.kernels.mxfp4_matmul import ops as mm_ops

    seen = {}

    def fake_mm(x, codes, exps, interpret=None, **kw):
        seen["mm"] = interpret
        return jnp.zeros((x.shape[0], codes.shape[-1]), jnp.bfloat16)

    def fake_cim(x, w, calib, cfg=None, interpret=None, **kw):
        seen["cim"] = interpret
        return jnp.zeros((x.shape[0], w.codes.shape[1]), jnp.float32)

    monkeypatch.setattr(mm_ops, "mxfp4_matmul", fake_mm)
    monkeypatch.setattr(cim_ops, "cim_linear", fake_cim)

    p, _ = linear_init(jax.random.PRNGKey(0), 64, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    packed = backends.quantize_linear_params(p)
    cfg = cimlib.CIMConfig()
    cal = cimlib.calibrate_rowhist([x], mxlib.quantize_w(p["w"]), cfg)
    cim_node = backends.get_backend("cim").convert(p, cal)

    ctx = dataclasses.replace(CTX, impl="pallas", interpret=False, cim=cfg)
    linear_apply(ctx, packed, x)
    linear_apply(ctx, cim_node, x)
    assert seen == {"mm": False, "cim": False}


# ------------------------------------------------- model-wide calibration

def _tiny_setup(arch="h2o-danube-1.8b", cim_cfg=None, min_n=32):
    cfg = C.tiny(C.ARCHS[arch])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    batches = calibrate.calibration_batches(cfg, n_batches=2, batch=2, seq=16)
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, CTX, batches, cim_cfg=cim_cfg, min_n=min_n
    )
    return cfg, params, batches, conv, calibs


def test_calibration_keys_and_stacked_conversion():
    cfg, params, batches, conv, calibs = _tiny_setup()
    # per-layer keys for the scanned segment + the top-level head
    assert "segments/0/L0/ffn/w1" in calibs
    assert "segments/0/L1/ffn/w1" in calibs
    assert "lm_head" in calibs
    node = conv["segments"][0]["ffn"]["w1"]
    assert node["codes"].dtype == jnp.int8
    assert node["codes"].shape == (cfg.n_layers, cfg.d_model, cfg.d_ff)
    assert node["e_n"].shape == (cfg.n_layers,)
    assert node["adc_fs"].shape == (cfg.n_layers,)
    # stacked calib really is per-layer: slices match per-layer calibration
    for j in range(cfg.n_layers):
        assert int(node["e_n"][j]) == int(calibs[f"segments/0/L{j}/ffn/w1"].e_n)
    # head converted un-stacked
    assert conv["lm_head"]["e_n"].shape == ()


def test_hybrid_lossless_cim_equals_digital_mxfp4_model():
    """With no ADC and an unbounded mirror window the hybrid analog model
    IS the digital MXFP4 model — end-to-end, through attention, FFN and
    head. This pins the whole backend wiring exactly.

    The bitwise identity is asserted under unrolled op-by-op execution
    (``unroll_layers``): inside ``lax.scan`` XLA fuses each model's whole
    layer body, and 1-ulp fusion differences in log2/div between the two
    *different* graphs flip MXFP4 codes at rounding boundaries — a
    compiler artifact, not a wiring difference (scan mode gets a bounded
    check instead)."""
    lossless = cimlib.CIMConfig(adc_bits=None, cm_bits=64, two_pass=False)
    cfg, params, batches, conv, _ = _tiny_setup(cim_cfg=lossless)
    dig_ctx = dataclasses.replace(CTX, quant="mxfp4_digital",
                                  unroll_layers=True)
    hyb_ctx = dataclasses.replace(CTX, quant="cim", cim=lossless,
                                  unroll_layers=True)
    d, _ = lm.forward(params, cfg, dig_ctx, batches[0])
    h, _ = lm.forward(conv, cfg, hyb_ctx, batches[0])
    d = np.asarray(d, np.float32)
    h = np.asarray(h, np.float32)
    assert _sqnr_db(d, h) > 60.0  # bf16-cast-level identity (measured ~300)
    assert (d.argmax(-1) == h.argmax(-1)).all()
    # scanned execution: same wiring, fused compilation — bounded instead
    # of bitwise (measured ~23 dB on this seed; boundary-flip noise)
    ds, _ = lm.forward(params, cfg,
                       dataclasses.replace(dig_ctx, unroll_layers=False),
                       batches[0])
    hs, _ = lm.forward(conv, cfg,
                       dataclasses.replace(hyb_ctx, unroll_layers=False),
                       batches[0])
    assert _sqnr_db(np.asarray(ds, np.float32),
                    np.asarray(hs, np.float32)) > 12.0


def test_hybrid_paper_operating_point_bounds_logit_error():
    """10b ADC + CM=3 2-pass Row-Hist: deviation vs the digital MXFP4
    baseline stays bounded on the calibration distribution (the paper's
    <1% accuracy-preservation claim scaled to a random-init smoke model,
    where near-uniform logits are the worst case for agreement)."""
    cim_cfg = cimlib.CIMConfig()
    cfg, params, batches, conv, _ = _tiny_setup(cim_cfg=cim_cfg)
    dig_ctx = dataclasses.replace(CTX, quant="mxfp4_digital")
    hyb_ctx = dataclasses.replace(CTX, quant="cim", cim=cim_cfg)
    d, _ = lm.forward(params, cfg, dig_ctx, batches[0])
    h, _ = lm.forward(conv, cfg, hyb_ctx, batches[0])
    d = np.asarray(d, np.float32)
    h = np.asarray(h, np.float32)
    assert _sqnr_db(d, h) > 5.0  # measured ~8.8 on this seed
    agree = (d.argmax(-1) == h.argmax(-1)).mean()
    assert agree > 0.35  # measured ~0.56
    # and the error per logit stays small vs the logit scale
    rel = np.abs(h - d).max() / max(np.abs(d).max(), 1e-6)
    assert rel < 1.0


def test_hybrid_decode_runs_jitted():
    cim_cfg = cimlib.CIMConfig()
    cfg, params, batches, conv, _ = _tiny_setup(cim_cfg=cim_cfg)
    hyb_ctx = dataclasses.replace(CTX, quant="cim", cim=cim_cfg)
    ids0 = batches[0]["ids"]
    b, s = ids0.shape
    caches = lm.init_cache(cfg, b, s + 4)
    logits, caches = lm.forward(conv, cfg, hyb_ctx, batches[0], caches=caches)
    ids = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]
    step = jax.jit(
        lambda p, c, i, pos: lm.decode_step(p, cfg, hyb_ctx, i, pos, c)
    )
    for t in range(3):
        lo, caches = step(conv, caches, ids, jnp.int32(s + t))
        assert lo.shape == (b, cfg.vocab_size)
        ids = jnp.argmax(lo.astype(jnp.float32), -1)[:, None]


def test_moe_experts_stay_digital_under_cim_conversion():
    cfg = C.tiny(C.ARCHS["mixtral-8x22b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    batches = calibrate.calibration_batches(cfg, n_batches=1, batch=2, seq=16)
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, CTX, batches, min_n=32
    )
    moe = conv["segments"][0]["moe"]
    assert "codes" in moe["w1"] and "e_n" not in moe["w1"]  # packed digital
    assert moe["w1"]["codes"].dtype == jnp.uint8
    assert "e_n" in conv["segments"][0]["attn"]["wq"]  # projections analog
    # hybrid forward runs (experts digital, projections analog)
    hyb_ctx = dataclasses.replace(CTX, quant="cim")
    logits, _ = lm.forward(conv, cfg, hyb_ctx, batches[0])
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
