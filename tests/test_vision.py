"""Vision subsystem: executable ViT models on the hybrid CIM stack +
image-stream FWS serving.

The crux checks, mirroring ``tests/test_backends.py`` for the encoder
family:

- *Backend invariant*: with a lossless CIM config the hybrid analog ViT
  is numerically identical to the fully digital MXFP4 ViT (unrolled);
  at the paper operating point the float<->cim top-1 agreement on
  synthetic images is bounded and asserted.
- *Pipeline fidelity*: the FWS pipeline steady-state FPS driven by the
  ViT engine's *measured* stage traffic matches PAPER_TABLE7 within 5%
  for vit-b16 (single chip) and vit-l32 (dual chip, 12+12 partition).
- *Encoder attention* (satellite): bidirectional dense-vs-flash equality
  at a non-multiple-of-chunk length (N=197, the ViT-B/16 token count) —
  the KV_PAD masking fix exercised on the non-causal path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core import cim as cimlib
from repro.core.metrics import sqnr_db
from repro.distributed.sharding import stage_partition
from repro.hwmodel import perf, specs as S
from repro.layers import attention as attn_mod
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, vit
from repro.serving import pipeline as pipe
from repro.serving.vision import VisionEngine, synthetic_stream_report

CTX = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
TINY = C.tiny_vit(C.VISION_ARCHS["vit-b16"])


@pytest.fixture(scope="module")
def tiny_model():
    params, _ = vit.init_model(jax.random.PRNGKey(0), TINY)
    batches = vit.calibration_images(TINY, n_batches=2, batch=2)
    return params, batches


@pytest.fixture(scope="module")
def cim_tiny(tiny_model):
    params, batches = tiny_model
    cim_cfg = cimlib.CIMConfig()
    conv, calibs = calibrate.convert_model_cim(
        params, TINY, CTX, batches, cim_cfg=cim_cfg, min_n=32,
        forward_fn=vit.forward,
    )
    return conv, calibs, dataclasses.replace(CTX, quant="cim", cim=cim_cfg)


# ------------------------------------------------------------- geometry

def test_configs_match_hwmodel_workloads():
    """The executable configs bill exactly the token traffic the paper's
    analytical model (and Table 7) uses."""
    for name in ("vit-b16", "vit-l32"):
        cfg = C.VISION_ARCHS[name]
        w = S.WORKLOADS[name]
        assert cfg.seq_len == w.seq, name
        assert cfg.d_model == w.d, name
        assert cfg.n_layers == w.layers, name
        assert cfg.chips == w.chips, name
    assert C.VISION_ARCHS["vit-l32"].chips == 2


def test_geometry_tiny_preserves_traffic_shape():
    for name in ("vit-b16", "vit-l32"):
        full = C.VISION_ARCHS[name]
        g = C.geometry_tiny_vit(full)
        assert g.seq_len == full.seq_len
        assert g.n_layers == full.n_layers
        assert g.chips == full.chips
        assert g.d_model < full.d_model


def test_patchify():
    img = jnp.arange(2 * 8 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 8, 3)
    p = vit.patchify(img, 4)
    assert p.shape == (2, 4, 48)
    # first patch is the top-left 4x4 block, row-major
    np.testing.assert_array_equal(
        np.asarray(p[0, 0]).reshape(4, 4, 3), np.asarray(img[0, :4, :4])
    )
    # tiny config keeps the patch projection analog-eligible
    assert TINY.patch_dim % 32 == 0


# ------------------------------------------------- backends on the vit

def test_forward_runs_under_all_backends(tiny_model):
    params, batches = tiny_model
    img = batches[0]
    outs = {}
    for name, (p, ctx) in {
        "float": (params, CTX),
        "mxfp4_digital": (params,
                          dataclasses.replace(CTX, quant="mxfp4_digital")),
        "mxfp4_wonly": (convert_params_mxfp4(params, min_n=32),
                        dataclasses.replace(CTX, quant="mxfp4_wonly")),
    }.items():
        lo, cache = vit.forward(p, TINY, ctx, img)
        assert cache is None
        assert lo.shape == (2, TINY.n_classes)
        assert bool(jnp.isfinite(lo.astype(jnp.float32)).all()), name
        outs[name] = np.asarray(lo, np.float32)
    # weight-only quant stays close to float on a tiny model (measured
    # ~8.5 dB on this random-init seed; near-uniform logits are the
    # worst case)
    assert sqnr_db(outs["float"], outs["mxfp4_wonly"]) > 5.0


def test_calibration_paths_cover_patch_trunk_and_head(cim_tiny):
    conv, calibs, _ = cim_tiny
    assert "patch" in calibs and "head" in calibs
    for j in range(TINY.n_layers):
        for leaf in ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
                     "ffn/w1", "ffn/w2"):
            assert f"segments/0/L{j}/{leaf}" in calibs
    # converted trunk is layer-stacked with per-layer calib
    node = conv["segments"][0]["ffn"]["w1"]
    assert node["codes"].shape == (TINY.n_layers, TINY.d_model, TINY.d_ff)
    assert node["e_n"].shape == (TINY.n_layers,)
    assert conv["patch"]["e_n"].shape == ()
    assert conv["head"]["e_n"].shape == ()


def test_vit_lossless_cim_equals_digital_mxfp4(tiny_model):
    """Tiny-ViT invariant mirroring tests/test_backends.py: the lossless
    hybrid analog ViT IS the digital MXFP4 ViT — through patch embedding,
    bidirectional SDPA, FFN and head — under unrolled op-by-op execution
    (scan-fusion 1-ulp boundary flips make cross-graph checks bounded,
    not bitwise; see test_backends.py docstring)."""
    params, batches = tiny_model
    lossless = cimlib.CIMConfig(adc_bits=None, cm_bits=64, two_pass=False)
    conv, _ = calibrate.convert_model_cim(
        params, TINY, CTX, batches, cim_cfg=lossless, min_n=32,
        forward_fn=vit.forward,
    )
    dig_ctx = dataclasses.replace(CTX, quant="mxfp4_digital",
                                  unroll_layers=True)
    hyb_ctx = dataclasses.replace(CTX, quant="cim", cim=lossless,
                                  unroll_layers=True)
    d, _ = vit.forward(params, TINY, dig_ctx, batches[0])
    h, _ = vit.forward(conv, TINY, hyb_ctx, batches[0])
    d = np.asarray(d, np.float32)
    h = np.asarray(h, np.float32)
    assert sqnr_db(d, h) > 60.0  # measured ~299
    assert (d.argmax(-1) == h.argmax(-1)).all()
    # scanned execution: same wiring, fused compilation -> bounded
    ds, _ = vit.forward(
        params, TINY,
        dataclasses.replace(dig_ctx, unroll_layers=False), batches[0]
    )
    hs, _ = vit.forward(
        conv, TINY,
        dataclasses.replace(hyb_ctx, unroll_layers=False), batches[0]
    )
    assert sqnr_db(np.asarray(ds, np.float32),
                   np.asarray(hs, np.float32)) > 12.0


def test_vit_paper_operating_point_top1_agreement(tiny_model, cim_tiny):
    """Float-vs-cim top-1 agreement at the paper operating point (10b
    ADC, CM=3, 2-pass) on synthetic images, bounded and asserted.
    Random-init near-uniform logits are the worst case: even
    float-vs-*digital* agreement is only ~0.2-0.5 here (the MXFP4 delta,
    not the analog stage, dominates — measured f-d 0.19 / f-h 0.25 /
    d-h 0.63 on this seed), so the bounds are (a) far above the 1/32
    chance rate and (b) the analog stage costs little on top of the
    digital quantization."""
    params, batches = tiny_model
    conv, _, hyb_ctx = cim_tiny
    images = vit.calibration_images(TINY, n_batches=1, batch=16, seed=77)[0]
    f, _ = vit.forward(params, TINY, CTX, images)
    d, _ = vit.forward(
        params, TINY, dataclasses.replace(CTX, quant="mxfp4_digital"), images
    )
    h, _ = vit.forward(conv, TINY, hyb_ctx, images)
    f = np.asarray(f, np.float32)
    d = np.asarray(d, np.float32)
    h = np.asarray(h, np.float32)
    assert sqnr_db(d, h) > 5.0  # analog effects vs the digital baseline
    agree_fh = float((f.argmax(-1) == h.argmax(-1)).mean())
    agree_fd = float((f.argmax(-1) == d.argmax(-1)).mean())
    agree_dh = float((d.argmax(-1) == h.argmax(-1)).mean())
    chance = 1.0 / TINY.n_classes
    assert agree_fh >= 4 * chance  # measured 0.25 vs chance 0.031
    assert agree_fh >= agree_fd - 0.2  # analog adds little on top of MXFP4
    assert agree_dh >= 0.5  # the analog-only delta (measured 0.63)
    rel = np.abs(h - f).max() / max(np.abs(f).max(), 1e-6)
    assert rel < 1.0


# ------------------------------------- satellite: encoder attention path

def _rand_qkv(key, b, s, hkv, g, dh):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, hkv, g, dh), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, dh), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, dh), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("n", [197, 145])
def test_encoder_dense_vs_flash_non_multiple_of_chunk(n):
    """Bidirectional (non-causal) dense-vs-flash equality at the paper's
    encoder token counts (197 = ViT-B/16, 145 = ViT-L/32) — both are
    non-multiples of the KV/Q chunk, so the flash path pads keys with
    KV_PAD positions; the PR-2 ``_mask`` fix must exclude them on the
    non-causal path too, else every query attends garbage pad keys."""
    cfg = attn_mod.AttnStatic(d_model=32, n_heads=2, n_kv=2, head_dim=16,
                              causal=False, use_rope=False)
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), 2, n, 2, 1, 16)
    pos = jnp.broadcast_to(jnp.arange(n)[None], (2, n))
    dense = attn_mod._dense_attn(q, k, v, pos, pos, cfg)
    ctx = dataclasses.replace(CTX, attn_chunk=64, q_chunk=64)
    flash = attn_mod._flash_attn(q, k, v, pos, pos, cfg, ctx)
    np.testing.assert_allclose(
        np.asarray(flash, np.float32), np.asarray(dense, np.float32),
        rtol=2e-5, atol=2e-5,
    )


def test_encoder_dense_vs_flash_mx_digital_bounded():
    """Digital-MXFP4 SDPA: dense and flash quantize P/V at different
    granularity (whole key axis vs per KV tile) so they are statistically
    — not bitwise — equivalent; pin the bound at N=197."""
    cfg = attn_mod.AttnStatic(d_model=32, n_heads=2, n_kv=2, head_dim=16,
                              causal=False, use_rope=False)
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), 2, 197, 2, 1, 16)
    pos = jnp.broadcast_to(jnp.arange(197)[None], (2, 197))
    dense = attn_mod._dense_attn(q, k, v, pos, pos, cfg, mx_digital=True)
    ctx = dataclasses.replace(CTX, attn_chunk=64, q_chunk=64)
    flash = attn_mod._flash_attn(q, k, v, pos, pos, cfg, ctx,
                                 mx_digital=True)
    assert sqnr_db(np.asarray(dense, np.float32),
                   np.asarray(flash, np.float32)) > 10.0  # measured ~14


# ---------------------------------------------------- chip partitioning

def test_stage_partition():
    assert stage_partition(24, 2) == [(0, 12), (12, 24)]
    assert stage_partition(12, 1) == [(0, 12)]
    assert stage_partition(5, 2) == [(0, 3), (3, 5)]
    with pytest.raises(ValueError):
        stage_partition(4, 5)
    with pytest.raises(ValueError):
        stage_partition(4, 0)


def test_dual_chip_split_matches_monolithic_float():
    cfg = dataclasses.replace(TINY, n_layers=4, chips=2)
    params, _ = vit.init_model(jax.random.PRNGKey(3), cfg)
    img = vit.calibration_images(cfg, n_batches=1, batch=2, seed=5)[0]
    mono, _ = vit.forward(params, cfg, CTX, img)
    x = img["images"]
    chips = vit.split_chips(params, cfg, 2)
    assert [n for _, n in chips] == [2, 2]
    for ci, (chip_params, n) in enumerate(chips):
        x = vit.forward_chip(chip_params, cfg, CTX, x, n,
                             first=ci == 0, last=ci == len(chips) - 1)
    np.testing.assert_array_equal(np.asarray(x, np.float32),
                                  np.asarray(mono, np.float32))


def test_dual_chip_split_matches_monolithic_cim(tiny_model, cim_tiny):
    """The chip chain slices resident analog nodes (codes, exps, per-layer
    e_n/adc_fs) along the layer axis exactly like weights."""
    _, batches = tiny_model
    conv, _, hyb_ctx = cim_tiny

    def chip_chain(ctx):
        x = batches[0]["images"]
        chips = vit.split_chips(conv, TINY, 2)
        for ci, (chip_params, n) in enumerate(chips):
            x = vit.forward_chip(chip_params, TINY, ctx, x, n,
                                 first=ci == 0, last=ci == len(chips) - 1)
        return np.asarray(x, np.float32)

    # op-by-op (unrolled) execution: bitwise — the slice really carries
    # the per-layer calibration with the weights
    u_ctx = dataclasses.replace(hyb_ctx, unroll_layers=True)
    mono_u, _ = vit.forward(conv, TINY, u_ctx, batches[0])
    np.testing.assert_array_equal(chip_chain(u_ctx),
                                  np.asarray(mono_u, np.float32))
    # scanned monolithic vs per-chip graphs: bounded (cross-graph 1-ulp
    # MXFP4 boundary flips; see test_backends.py docstring; measured ~11)
    mono_s, _ = vit.forward(conv, TINY, hyb_ctx, batches[0])
    assert sqnr_db(np.asarray(mono_s, np.float32), chip_chain(hyb_ctx)) > 8.0


# ------------------------------------------------ FWS pipeline fidelity

def _streamed_engine(workload, n_frames=3, chips=None):
    cfg = C.geometry_tiny_vit(C.VISION_ARCHS[workload])
    params, _ = vit.init_model(jax.random.PRNGKey(0), cfg)
    eng = VisionEngine(params, cfg, CTX, chips=chips)
    frames = jax.random.normal(
        jax.random.PRNGKey(1), (n_frames, cfg.image_size, cfg.image_size, 3)
    )
    labels = eng.stream(frames)
    assert len(labels) == n_frames
    assert eng.trace == [cfg.seq_len] * n_frames  # measured stage traffic
    return eng


def test_vit_b16_measured_traffic_matches_table7():
    """Acceptance: steady-state FPS from the engine's measured traffic
    matches PAPER_TABLE7 within 5% for vit-b16 (single chip)."""
    eng = _streamed_engine("vit-b16")
    rep = eng.fws_report(workload="vit-b16")
    assert rep.chips == 1 and rep.n_tokens == 197
    assert rep.fps == pytest.approx(S.PAPER_TABLE7["vit-b16"][1], rel=0.05)
    assert rep.fps == pytest.approx(perf.steady_state_fps(197, 768),
                                    rel=1e-6)


def test_vit_l32_dual_chip_measured_traffic_matches_table7():
    """Acceptance: vit-l32 dual-chip (24 layers split 12+12 with an
    inter-chip hop) within 5% of the paper's 58,275 FPS."""
    eng = _streamed_engine("vit-l32")
    assert eng.chips == 2
    assert len(eng._chain) == 2  # 12+12 stage partition drove execution
    rep = eng.fws_report(workload="vit-l32")
    assert rep.chips == 2 and rep.n_tokens == 145
    assert rep.fps == pytest.approx(S.PAPER_TABLE7["vit-l32"][1], rel=0.05)
    # the hop deepens the pipeline but never bounds throughput ...
    t = perf.stage_time(145, 1024)
    hop = perf.t_interchip(145, 1024)
    assert 0 < hop < t
    assert rep.fps == pytest.approx(1.0 / t, rel=1e-6)
    # ... and one frame's fill latency is 24 compute stages + one hop
    assert rep.frame_latency_s == pytest.approx(24 * t + hop, rel=1e-9)


def test_traffic_shaped_rows_vit_b32_and_bert_base():
    for name in ("vit-b32", "bert-base"):
        w = S.WORKLOADS[name]
        rep = synthetic_stream_report(w.seq, w.d, chips=w.chips)
        assert rep.fps == pytest.approx(S.PAPER_TABLE7[name][1], rel=0.05)


def test_fws_report_guards():
    eng = _streamed_engine("vit-b16")
    with pytest.raises(ValueError, match="measured stage traffic"):
        eng.fws_report(workload="bert-base")  # 197 != 512 tokens
    empty = VisionEngine(*vit.init_model(jax.random.PRNGKey(0), TINY)[:1],
                         TINY, CTX)
    with pytest.raises(ValueError, match="no frames"):
        empty.fws_report()


def test_multichip_pipeline_model_properties():
    """chips=1 is exactly the legacy simulate; chips=2 keeps throughput
    but deepens latency by one chip's stages + the hop."""
    jobs = [pipe.Job(0.0, 145) for _ in range(80)]
    one = pipe.simulate(jobs, 1024)
    two = pipe.simulate(jobs, 1024, chips=2)
    assert two.steady_state_fps == pytest.approx(one.steady_state_fps,
                                                 rel=1e-9)
    t = perf.stage_time(145, 1024)
    hop = perf.t_interchip(145, 1024)
    assert one.timings[0].latency == pytest.approx(12 * t)
    assert two.timings[0].latency == pytest.approx(24 * t + hop)
