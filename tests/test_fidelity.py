"""Numerical-fidelity observability: SQNR tracer, quantizer/ADC health
counters, and the calibration-drift detector.

The crux facts these tests pin:

- ``cim_linear_fidelity`` returns the *same* ``y`` as ``cim_linear``
  bit-for-bit — instrumentation only adds counters, never perturbs the
  serving numerics;
- an under-scaled ADC full scale produces a non-zero saturation counter
  AND a degraded SQNR *in the same run* (the correlation the drift
  detector exists to surface), while the well-calibrated layer shows
  zero saturation and the better SQNR;
- the drift detector is self-consistent on calibration traffic (zero
  verdicts) and fires on a deliberately shrunken ``adc_fs``;
- with ``Obs(enabled=False)`` the whole probe is a no-op: no records,
  no registry families, no drift verdicts.
"""

import dataclasses
from bisect import bisect_left

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro import obs as obs_lib
from repro.core import cim as cimlib
from repro.core import mx as mxlib
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import calibrate, lm
from repro.obs import EXP_BUCKETS, RATIO_BUCKETS
from repro.obs.fidelity import FidelityProbe, sqnr_db, sqnr_trace

CTX = RunCtx(shd=ShardingCtx(), dense_attn_max=256)


# ------------------------------------------------------------ sqnr sentinel

def test_sqnr_all_zero_reference_is_nan():
    assert np.isnan(sqnr_db(np.zeros(8), np.ones(8)))
    assert np.isnan(sqnr_db(np.zeros(8), np.zeros(8)))
    assert np.isfinite(sqnr_db(np.ones(8), np.ones(8) * 1.01))


def test_sqnr_trace_matches_paths_with_equal_shapes():
    a = {"x": np.ones((4, 8)), "y": np.ones((2, 8)), "only_ref": np.ones(3)}
    b = {"x": np.ones((4, 8)) * 1.1, "y": np.ones((3, 8))}
    per = sqnr_trace(a, b)
    assert set(per) == {"x"}  # "y" shape mismatch, "only_ref" unmatched


# ------------------------------------------------- device/host histograms

def test_bucket_counts_matches_host_bisect():
    rng = np.random.default_rng(0)
    v = rng.integers(-30, 30, size=257).astype(np.float32)
    dev = np.asarray(mxlib.bucket_counts(jnp.asarray(v), EXP_BUCKETS))
    host = np.zeros(len(EXP_BUCKETS) + 1, np.int64)
    for x in v:
        host[bisect_left(EXP_BUCKETS, x)] += 1
    assert (dev == host).all()
    assert dev.sum() == v.size


def test_histogram_merge_counts_accumulates():
    reg = obs_lib.MetricsRegistry()
    h = reg.histogram("h", "t", buckets=RATIO_BUCKETS)
    counts = np.zeros(len(RATIO_BUCKETS) + 1, np.int64)
    counts[0], counts[-1] = 3, 1
    h.merge_counts(counts, sum=1.3, count=4, vmin=0.01, vmax=1.7)
    h.merge_counts(counts, sum=1.3, count=4, vmin=0.005, vmax=1.2)
    assert h.count == 8 and h.sum == pytest.approx(2.6)
    assert h.min == pytest.approx(0.005) and h.max == pytest.approx(1.7)
    assert h.counts[0] == 6 and h.counts[-1] == 2
    with pytest.raises(ValueError):
        h.merge_counts(counts[:-1], sum=0.0, count=1, vmin=0.0, vmax=0.0)
    # zero-count merge is a no-op (no min/max pollution)
    h.merge_counts(np.zeros_like(counts), sum=0.0, count=0, vmin=9.0,
                   vmax=-9.0)
    assert h.count == 8 and h.max == pytest.approx(1.7)


# ----------------------------------------------------- quantizer health

def test_quant_health_counts_clip_and_underflow():
    x = np.zeros((2, mxlib.BLOCK), np.float32)
    x[0, 0] = 1.0
    x[0, 1] = 1e-8   # underflows to zero code next to a 1.0 block max
    x[1, :] = 3e38   # beyond FP4_MAX * 2^125 (the biased-exponent clamp)
    h = jax.device_get(mxlib.quant_health(jnp.asarray(x), EXP_BUCKETS))
    assert h["total"] == 2 * mxlib.BLOCK
    assert int(h["underflow"]) == 1
    assert int(h["clipped"]) == mxlib.BLOCK
    assert int(h["exp_n"]) == 2  # two live blocks
    assert int(h["exp_min"]) == -2 and int(h["exp_max"]) == 125
    assert int(np.sum(h["exp_counts"])) == 2


def test_quant_health_all_zero_input():
    h = jax.device_get(
        mxlib.quant_health(jnp.zeros((1, mxlib.BLOCK)), EXP_BUCKETS)
    )
    assert int(h["underflow"]) == 0 and int(h["clipped"]) == 0
    assert int(h["exp_n"]) == 0


# ------------------------------------- single-layer ADC health + bitwise

def _layer_setup(seed=0, t=32, k=64, m=64):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, k)), jnp.float32)
    w = mxlib.quantize_w(
        jnp.asarray(rng.standard_normal((k, m)) / np.sqrt(k), jnp.float32)
    )
    cfg = cimlib.CIMConfig()
    calib = cimlib.calibrate_rowhist([x], w, cfg)
    return x, w, cfg, calib


def test_fidelity_linear_is_bitwise_cim_linear():
    x, w, cfg, calib = _layer_setup()
    y_ref, _ = cimlib.cim_linear(x, w, cfg, calib)
    y_fid, stats = cimlib.cim_linear_fidelity(x, w, cfg, calib,
                                              code_buckets=RATIO_BUCKETS)
    assert (np.asarray(y_ref) == np.asarray(y_fid)).all()
    # occupancy histogram covers every ADC sample of both passes
    assert int(stats["pass1"]["total"]) == y_ref.size
    assert int(np.sum(np.asarray(stats["pass1"]["occ_counts"]))) == y_ref.size


def test_underscaled_fs_saturates_and_degrades_sqnr_same_run():
    """Satellite invariant: shrinking ``adc_fs`` must show up in BOTH the
    saturation counter and the SQNR, in one run — and the well-calibrated
    layer must show the inverse (zero saturation, better SQNR)."""
    x, w, cfg, calib = _layer_setup()
    ref = mxlib.dequantize(mxlib.quantize(x), out_len=w.codes.shape[0]) \
        @ mxlib.dequantize_w(w)
    ref = np.asarray(ref, np.float64)

    y_good, s_good = cimlib.cim_linear_fidelity(x, w, cfg, calib)
    bad_calib = calib._replace(adc_fs=calib.adc_fs * 0.25)
    y_bad, s_bad = cimlib.cim_linear_fidelity(x, w, cfg, bad_calib)

    sat_good = int(s_good["pass1"]["saturated"])
    sat_bad = int(s_bad["pass1"]["saturated"])
    # Row-Hist full scale is the max |column sum| of this batch: exact
    # self-consistency at the single-layer level
    assert sat_good == 0
    assert sat_bad > 0
    db_good = sqnr_db(ref, np.asarray(y_good, np.float64))
    db_bad = sqnr_db(ref, np.asarray(y_bad, np.float64))
    assert db_bad < db_good - 3.0


# ----------------------------------------------------- probe no-op gate

def test_disabled_obs_probe_is_noop():
    probe = FidelityProbe(obs=obs_lib.Obs(enabled=False))
    # none of these may touch the arguments when disabled
    probe.observe_linear("p", None, None, None)
    probe.note_sqnr({"p": 3.0})
    rep = probe.drift_report()
    assert probe.records == {}
    assert rep == {"layers": {}, "drifted": [], "n_drifted": 0}
    assert probe.summary() == {}
    assert probe.registry.families() == []


# ----------------------------------------------------- scale_adc_fs tool

def test_scale_adc_fs_scales_only_matching_layers():
    tree = {
        "a": {"adc_fs": 8.0, "e_n": 1},
        "b": {"nested": [{"adc_fs": 4.0}]},
        "w": np.ones(3),
    }
    out = obs_lib.scale_adc_fs(tree, 0.5, match="nested")
    assert out["b"]["nested"][0]["adc_fs"] == 2.0
    assert out["a"]["adc_fs"] == 8.0  # unmatched path untouched
    assert tree["b"]["nested"][0]["adc_fs"] == 4.0  # original not mutated
    all_scaled = obs_lib.scale_adc_fs(tree, 0.5)
    assert all_scaled["a"]["adc_fs"] == 4.0
    assert all_scaled["b"]["nested"][0]["adc_fs"] == 2.0


# --------------------------------------------- NaN-safe metric export

def test_nan_gauge_survives_export():
    reg = obs_lib.MetricsRegistry()
    reg.gauge("g", "t", labels={"layer": "l"}).set(float("nan"))
    prom = obs_lib.to_prometheus(reg)
    assert 'g{layer="l"} NaN' in prom
    snap = obs_lib.to_json(reg)
    assert snap["metrics"]["g"]["series"][0]["value"] is None  # JSON-safe


# ------------------------------------------------- model-level end-to-end

@pytest.fixture(scope="module")
def tiny_hybrid():
    cfg = C.tiny(C.ARCHS["h2o-danube-1.8b"])
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    batches = calibrate.calibration_batches(cfg, n_batches=2, batch=2,
                                            seq=16)
    conv, calibs = calibrate.convert_model_cim(
        params, cfg, CTX, batches, min_n=32
    )
    return cfg, params, batches, conv, calibs


def test_model_fidelity_pass_publishes_everything(tiny_hybrid):
    cfg, params, batches, conv, calibs = tiny_hybrid
    ctx = dataclasses.replace(CTX, quant="cim", cim=cimlib.CIMConfig())
    probe, rep = obs_lib.run_fidelity_pass(
        params, conv, cfg, ctx, batches[0]
    )
    snap = probe.registry.snapshot()

    def layers_of(name):
        return {s["labels"]["layer"] for s in snap[name]["series"]}

    sqnr_layers = layers_of("fidelity_sqnr_db")
    clip_layers = layers_of("fidelity_mxfp4_clip_total")
    sat_layers = layers_of("adc_saturation_ratio")
    occ_layers = layers_of("adc_code_utilization")
    for path in calibs:  # every calibrated analog layer is covered
        assert path in sqnr_layers
        assert path in clip_layers
        assert path in sat_layers
        assert path in occ_layers
    assert "output" in rep["sqnr_db"]
    assert rep["sqnr_db"]["output"] > 5.0  # paper operating point
    # self-consistency: calibration traffic never reads as drifted
    assert rep["drift"]["n_drifted"] == 0


def test_model_miscalibration_trips_drift_and_degrades_sqnr(tiny_hybrid):
    cfg, params, batches, conv, calibs = tiny_hybrid
    ctx = dataclasses.replace(CTX, quant="cim", cim=cimlib.CIMConfig())
    _, good = obs_lib.run_fidelity_pass(params, conv, cfg, ctx, batches[0])
    bad_tree = obs_lib.scale_adc_fs(conv, 0.25)
    probe, bad = obs_lib.run_fidelity_pass(
        params, bad_tree, cfg, ctx, batches[0]
    )
    assert bad["drift"]["n_drifted"] > 0
    # the drift verdict correlates with measurable damage, per layer and
    # end to end
    for path in bad["drift"]["drifted"]:
        assert bad["layers"][path]["adc_saturation_ratio"] > 0.05
    assert bad["sqnr_db"]["output"] < good["sqnr_db"]["output"] - 3.0
