"""Analog CTT-CIM simulation: invariants + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cim, mx as mxlib


def _setup(seed=0, t=8, k=96, m=16, xscale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((t, k)) * xscale).astype(np.float32)
    w = rng.standard_normal((k, m)).astype(np.float32)
    return jnp.asarray(x), mxlib.quantize_w(jnp.asarray(w)), jnp.asarray(w)


def _mx_ref(x, wq, k):
    """Digital MXFP4 oracle: exact dot of the quantized operands."""
    xq = mxlib.quantize(x[..., :k])
    return np.asarray(mxlib.dequantize(xq, out_len=k)) @ np.asarray(
        mxlib.dequantize_w(wq)
    )


def test_bitplane_decomposition_exact():
    rng = np.random.default_rng(1)
    cx = jnp.asarray(rng.integers(-12, 13, size=(5, 32)), jnp.int8)
    cw = jnp.asarray(rng.integers(-12, 13, size=(5, 32)), jnp.int8)
    direct = np.sum(
        np.asarray(cx, np.int64) * np.asarray(cw, np.int64), axis=-1
    ).astype(np.float64)
    bp = np.asarray(cim.bitplane_dot(cx, cw), np.float64)
    np.testing.assert_array_equal(bp, direct)


def test_wide_window_no_adc_matches_digital_mxfp4():
    """With a huge CM budget and no ADC, the analog path must be *exactly*
    the digital MXFP4 matmul (alignment is lossless in-window)."""
    x, wq, _ = _setup()
    cfg = cim.CIMConfig(adc_bits=None, cm_bits=64, two_pass=False)
    calib = cim.calibrate_rowhist([x], wq, cfg)
    y, _ = cim.cim_linear(x, wq, cfg, calib)
    ref = _mx_ref(x, wq, 96)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-6, atol=1e-6)


def test_rowhist_eliminates_overflow():
    x, wq, _ = _setup(seed=2, xscale=3.0)
    cfg = cim.CIMConfig(adc_bits=None, cm_bits=3, collect_stats=True)
    calib = cim.calibrate_rowhist([x], wq, cfg)
    _, stats = cim.cim_linear(x, wq, cfg, calib)
    assert float(stats["overflow_rate"]) == 0.0


def test_two_pass_equals_double_cm_single_pass():
    """Row-Hist 2-pass at CM bits == single pass at 2*CM bits when the ADC
    is ideal (paper Fig 5: '2-Pass is effectively identical at half the CM
    correction bits')."""
    x, wq, _ = _setup(seed=3)
    cfg2 = cim.CIMConfig(adc_bits=None, cm_bits=3, two_pass=True)
    cfg1 = cim.CIMConfig(adc_bits=None, cm_bits=6, two_pass=False)
    calib = cim.calibrate_rowhist([x], wq, cfg2)
    y2, _ = cim.cim_linear(x, wq, cfg2, calib)
    y1, _ = cim.cim_linear(x, wq, cfg1, calib)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), rtol=1e-6, atol=1e-6)


def test_more_cm_bits_never_hurts():
    """Monotonicity: the set of exactly-represented blocks grows with CM."""
    x, wq, _ = _setup(seed=4)
    ref = _mx_ref(x, wq, 96)
    errs = []
    for cmb in (0, 1, 2, 3, 5, 8):
        cfg = cim.CIMConfig(adc_bits=None, cm_bits=cmb, two_pass=False)
        calib = cim.calibrate_rowhist([x], wq, cfg)
        y, _ = cim.cim_linear(x, wq, cfg, calib)
        errs.append(float(np.mean((np.asarray(y) - ref) ** 2)))
    assert all(a >= b - 1e-12 for a, b in zip(errs, errs[1:])), errs


def test_underflow_rate_decreases_with_cm():
    x, wq, _ = _setup(seed=5)
    rates = []
    for cmb in (0, 2, 4, 8):
        cfg = cim.CIMConfig(adc_bits=None, cm_bits=cmb, collect_stats=True)
        calib = cim.calibrate_rowhist([x], wq, cfg)
        _, stats = cim.cim_linear(x, wq, cfg, calib)
        rates.append(float(stats["underflow_rate_p1"]))
    assert all(a >= b for a, b in zip(rates, rates[1:])), rates


def test_unsigned_bias_column_equivalence():
    """Signed-weight path == unsigned [0,24] weights + bias column."""
    x, wq, _ = _setup(seed=6)
    for cmb, adc in ((3, None), (3, 10), (2, 8)):
        cfg = cim.CIMConfig(adc_bits=adc, cm_bits=cmb, two_pass=True)
        calib = cim.calibrate_rowhist([x], wq, cfg)
        y_s, _ = cim.cim_linear(x, wq, cfg, calib)
        y_u = cim.cim_linear_unsigned(x, wq, cfg, calib)
        np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_u), rtol=1e-6)


def test_adc_quantization_bounds():
    """ADC output is on the uniform grid and |err| <= delta/2 in-range."""
    x, wq, _ = _setup(seed=7)
    cfg0 = cim.CIMConfig(adc_bits=None, cm_bits=6, two_pass=False)
    cfg10 = cim.CIMConfig(adc_bits=10, cm_bits=6, two_pass=False)
    calib = cim.calibrate_rowhist([x], wq, cfg0)
    y0, _ = cim.cim_linear(x, wq, cfg0, calib)
    y10, _ = cim.cim_linear(x, wq, cfg10, calib)
    delta = float(calib.adc_fs) / 2**9 * float(mxlib.exp2i(calib.e_n)) * 0.25
    assert np.max(np.abs(np.asarray(y10) - np.asarray(y0))) <= delta * 0.5 + 1e-7


def test_adc_more_bits_better():
    x, wq, _ = _setup(seed=8, t=16)
    ref = _mx_ref(x, wq, 96)
    errs = []
    for bits in (6, 8, 10, 12):
        cfg = cim.CIMConfig(adc_bits=bits, cm_bits=3, two_pass=True)
        calib = cim.calibrate_rowhist([x], wq, cfg)
        y, _ = cim.cim_linear(x, wq, cfg, calib)
        errs.append(float(np.sqrt(np.mean((np.asarray(y) - ref) ** 2))))
    assert errs[0] > errs[2] and errs[1] > errs[3] * 0.99, errs


def test_online_strategies_run_and_are_worse():
    """Row0 / RowOpt online strategies underperform Row-Hist (Fig 5)."""
    x, wq, _ = _setup(seed=9, t=32)
    ref = _mx_ref(x, wq, 96)

    def err(cfg, calib=None):
        y, _ = cim.cim_linear(x, wq, cfg, calib)
        return float(np.mean((np.asarray(y) - ref) ** 2))

    cfg_rh = cim.CIMConfig(adc_bits=None, cm_bits=3, two_pass=True)
    calib = cim.calibrate_rowhist([x], wq, cfg_rh)
    e_rh = err(cfg_rh, calib)
    e_r0 = err(cim.CIMConfig(adc_bits=None, cm_bits=3, strategy="row0"))
    e_ro = err(cim.CIMConfig(adc_bits=None, cm_bits=3, strategy="row_opt"))
    assert e_rh <= e_r0 and e_rh <= e_ro, (e_rh, e_r0, e_ro)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 4), st.sampled_from([None, 10]))
def test_property_no_overflow_and_finite(seed, cmb, adc):
    """Under Row-Hist calibration on the same data: zero overflow events,
    finite outputs, and error decreases vs no mirror budget."""
    x, wq, _ = _setup(seed=seed, t=4, k=64, m=8,
                      xscale=10.0 ** ((seed % 5) - 2))
    cfg = cim.CIMConfig(adc_bits=adc, cm_bits=cmb, collect_stats=True)
    calib = cim.calibrate_rowhist([x], wq, cfg)
    y, stats = cim.cim_linear(x, wq, cfg, calib)
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(stats["overflow_rate"]) == 0.0


def test_jit_compatible():
    x, wq, _ = _setup(seed=10)
    cfg = cim.CIMConfig(adc_bits=10, cm_bits=3)
    calib = cim.calibrate_rowhist([x], wq, cfg)
    f = jax.jit(lambda xx: cim.cim_linear(xx, wq, cfg, calib)[0])
    y1 = f(x)
    y2, _ = cim.cim_linear(x, wq, cfg, calib)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
