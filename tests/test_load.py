"""Load-harness pieces: arrival processes, workload synthesis, trace
round-trips, and the wall-clock replay driver against the real engine.

The replay crux check piggybacks on lane isolation: whatever order the
wall clock admits requests in, per-request outputs must equal a plain
all-at-once engine run — so the harness adds queueing pressure without
perturbing results.
"""

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.layers.common import RunCtx, ShardingCtx
from repro.models import lm
from repro.obs import Obs, SLOTargets
from repro.serving import Engine, EngineConfig
from repro.serving import load as load_mod

CFG = C.tiny(C.ARCHS["starcoder2-7b"])


@pytest.fixture(scope="module")
def float_model():
    params, _ = lm.init_model(jax.random.PRNGKey(0), CFG)
    return params, RunCtx(shd=ShardingCtx(), dense_attn_max=256)


# ------------------------------------------------------------- arrivals

def test_poisson_arrivals():
    rng = np.random.default_rng(0)
    t = load_mod.poisson_arrivals(50.0, 500, rng)
    assert t.shape == (500,) and (np.diff(t) > 0).all()
    assert np.mean(np.diff(t)) == pytest.approx(1 / 50.0, rel=0.25)
    with pytest.raises(ValueError):
        load_mod.poisson_arrivals(0.0, 3, rng)


def test_burst_arrivals():
    t = load_mod.burst_arrivals(7, burst=3, gap_s=0.5)
    assert t.tolist() == [0.0, 0.0, 0.0, 0.5, 0.5, 0.5, 1.0]


def test_parse_arrivals():
    assert load_mod.parse_arrivals("poisson:25") == ("poisson", 25.0)
    assert load_mod.parse_arrivals("trace:/tmp/t.json") == (
        "trace", "/tmp/t.json")
    assert load_mod.parse_arrivals("burst:8:0.1") == ("burst", (8, 0.1))
    assert load_mod.parse_arrivals("burst:8") == ("burst", (8, 0.05))
    for bad in ("uniform:3", "trace:", "trace"):
        with pytest.raises(ValueError):
            load_mod.parse_arrivals(bad)


def test_trace_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    spec = load_mod.WorkloadSpec(vocab_size=64, max_prompt=10)
    trace = load_mod.make_trace(
        load_mod.poisson_arrivals(100.0, 5, rng),
        load_mod.synth_requests(spec, 5, rng),
    )
    p = tmp_path / "trace.json"
    load_mod.save_trace(str(p), trace)
    assert load_mod.load_trace(str(p)) == trace


def test_synth_requests_shared_prefixes():
    rng = np.random.default_rng(2)
    spec = load_mod.WorkloadSpec(
        vocab_size=64, prompt_len=(2, 6), out_len=(1, 4), n_system=2,
        system_len=4, p_shared=1.0, max_prompt=8,
    )
    reqs = load_mod.synth_requests(spec, 40, rng)
    systems = {tuple(p[:4]) for p, _ in reqs}
    assert len(systems) <= 2  # every prompt opens with a system prompt
    for p, m in reqs:
        assert 1 <= len(p) <= 8
        assert 1 <= m <= 4


# --------------------------------------------------------------- replay

def test_replay_matches_batch_run_and_reports(float_model):
    params, ctx = float_model
    ecfg = EngineConfig(lanes=2, num_slots=4, page_len=24, prefill_len=8,
                        policy="chunked", chunk_len=4, prefix_cache=True)
    rng = np.random.default_rng(3)
    spec = load_mod.WorkloadSpec(vocab_size=CFG.vocab_size,
                                 prompt_len=(2, 6), out_len=(2, 4),
                                 n_system=1, system_len=6, p_shared=0.75,
                                 max_prompt=16)
    reqs = load_mod.synth_requests(spec, 6, rng)
    trace = load_mod.make_trace(load_mod.burst_arrivals(6, 2, 0.01), reqs)

    eng = Engine(params, CFG, ctx, ecfg, obs=Obs(enabled=True))
    res = load_mod.replay(eng, trace, speed=4.0)
    assert sorted(res["out"]) == list(range(6))

    # lane isolation: wall-clock admission order cannot change outputs
    ref = Engine(params, CFG, ctx, ecfg, obs=Obs(enabled=False))
    for p, m in reqs:
        ref.add_request(list(p), max_new=m)
    ref_out = ref.run()
    assert res["out"] == {rid: ref_out[rid] for rid in res["out"]}

    rep = load_mod.load_report(
        eng, targets=SLOTargets(ttft_p99_s=60.0, token_p99_s=60.0),
        wall_s=res["wall_s"],
    )
    assert rep["n_requests"] == 6
    assert rep["tokens_generated"] == sum(len(v) for v in ref_out.values())
    assert rep["steps"]["prefill"] > 0 and rep["steps"]["decode"] > 0
    assert rep["ttft_s"]["p99"] > 0 and rep["token_latency_s"]["n"] > 0
    assert rep["prefix"]["hits"] > 0
    # generous targets on real samples -> a definite (non-None) verdict
    assert all(c["ok"] is True for c in rep["slo"]["checks"].values())
    assert rep["tokens_per_s_wall"] > 0
