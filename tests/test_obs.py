"""Telemetry subsystem invariants (host-side; no models).

Crux checks: the Prometheus text exposition round-trips through the
repo's own parser bit-for-bit in value space (the tier-1 exporter
acceptance), histogram quantiles are sane under the fixed-bucket
estimator, the span tracer derives the legacy trace view exactly, and
``profiled_call`` distinguishes eager dispatches (wall captured under
``profile=True``) from traced ones (counted only).
"""

import math

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs import export as export_mod
from repro.obs.registry import RATIO_BUCKETS


# ------------------------------------------------------------- registry

def test_counter_monotonic():
    r = obs.MetricsRegistry()
    c = r.counter("c_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = obs.MetricsRegistry().gauge("g", "")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_registry_type_conflict_raises():
    r = obs.MetricsRegistry()
    r.counter("m", "")
    with pytest.raises(ValueError):
        r.gauge("m", "")


def test_registry_label_series_distinct():
    r = obs.MetricsRegistry()
    a = r.counter("m_total", "", labels={"k": "a"})
    b = r.counter("m_total", "", labels={"k": "b"})
    a.inc(3)
    b.inc(5)
    assert (a.value, b.value) == (3, 5)
    # same label set -> same series object
    assert r.counter("m_total", "", labels={"k": "a"}) is a


def test_histogram_quantiles_uniform():
    h = obs.Histogram(buckets=tuple(float(i) for i in range(1, 101)))
    for i in range(1, 101):
        h.observe(i - 0.5)
    assert h.count == 100
    assert h.quantile(0.5) == pytest.approx(50, abs=1.0)
    assert h.quantile(0.99) == pytest.approx(99, abs=1.0)
    # clamped to observed extremes: no bucket-edge extrapolation
    assert h.quantile(0.0) >= h.min
    assert h.quantile(1.0) <= h.max
    p = h.percentiles()
    assert set(p) == {"p50", "p90", "p99"}


def test_histogram_empty_and_overflow():
    h = obs.Histogram(buckets=(1.0, 2.0))
    assert h.quantile(0.5) == 0.0
    h.observe(100.0)  # lands in +Inf bucket
    assert h.counts[-1] == 1
    assert h.quantile(0.99) == pytest.approx(100.0)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        obs.Histogram(buckets=(2.0, 1.0))


# ------------------------------------------------------------ exporters

def _populated_registry():
    r = obs.MetricsRegistry()
    r.counter("serve_requests_total", "requests in").inc(7)
    r.gauge("serve_queue_depth", "waiting").set(3)
    r.counter("steps_total", "by kind", labels={"kind": "decode"}).inc(4)
    r.counter("steps_total", "by kind", labels={"kind": "prefill"}).inc(2)
    h = r.histogram("serve_ttft_seconds", "ttft")
    for v in (1e-4, 2e-4, 5e-3, 0.1):
        h.observe(v)
    occ = r.histogram("occupancy", "ratio", buckets=RATIO_BUCKETS)
    occ.observe(0.75)
    r.gauge("weird", 'help with "quotes"\nand newline',
            labels={"path": 'a"b\\c'}).set(1.5)
    return r


def test_prometheus_round_trip():
    r = _populated_registry()
    text = export_mod.to_prometheus(r)
    samples = export_mod.parse_prometheus(text)
    assert samples[("serve_requests_total", ())] == 7
    assert samples[("serve_queue_depth", ())] == 3
    assert samples[("steps_total", (("kind", "decode"),))] == 4
    assert samples[("serve_ttft_seconds_count", ())] == 4
    assert samples[("serve_ttft_seconds_sum", ())] == pytest.approx(0.1053)
    assert samples[("weird", (("path", 'a"b\\c'),))] == 1.5
    # cumulative buckets: monotone, +Inf equals _count
    lad = sorted(
        (float("inf") if dict(ls)["le"] == "+Inf" else float(dict(ls)["le"]),
         v)
        for (name, ls), v in samples.items()
        if name == "serve_ttft_seconds_bucket"
    )
    counts = [v for _, v in lad]
    assert counts == sorted(counts)
    assert counts[-1] == 4


def test_prometheus_exposition_format_lines():
    text = export_mod.to_prometheus(_populated_registry())
    assert "# TYPE serve_requests_total counter" in text
    assert "# TYPE serve_ttft_seconds histogram" in text
    assert '_bucket{le="+Inf"} 4' in text


def test_json_snapshot_and_write(tmp_path):
    r = _populated_registry()
    snap = export_mod.to_json(r, extra={"slo": {"pass": True}})
    assert snap["slo"]["pass"] is True
    fam = snap["metrics"]["serve_ttft_seconds"]
    assert fam["type"] == "histogram"
    s = fam["series"][0]
    assert s["count"] == 4 and "p99" in s and "buckets" in s

    import json

    jp, pp = obs.write_metrics(r, str(tmp_path / "m.json"))
    assert pp.endswith(".prom")
    reloaded = json.load(open(jp))
    assert reloaded["metrics"]["serve_queue_depth"]["series"][0]["value"] == 3
    assert export_mod.parse_prometheus(open(pp).read())[
        ("serve_requests_total", ())
    ] == 7


# ------------------------------------------------------------ span tracer

def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 0.01
        return t[0]

    return clock


def test_obs_request_lifecycle_metrics():
    o = obs.Obs(clock=_fake_clock())
    o.request_enqueued(0, n_prompt=5, t=1.0)
    o.request_admitted(0, t=1.5)
    o.token_emitted(0, t=2.0)   # first token -> ttft
    o.token_emitted(0, t=2.25)  # inter-token gap
    o.request_finished(0, reason="max_new", t=3.0)
    [r] = o.finished
    assert r.queue_wait_s == pytest.approx(0.5)
    assert r.ttft_s == pytest.approx(1.0)
    assert r.e2e_s == pytest.approx(2.0)
    assert r.token_intervals_s == [pytest.approx(0.25)]
    assert o.registry.histogram("serve_ttft_seconds").count == 1
    assert o.registry.histogram("serve_token_latency_seconds").count == 1
    summ = o.request_summary()
    assert summ["n_requests"] == 1 and summ["n_tokens"] == 2
    assert summ["finish_reasons"] == {"max_new": 1}


def test_obs_eviction_counted():
    o = obs.Obs()
    o.request_enqueued(3)
    o.request_finished(3, reason="page_exhausted")
    assert o.registry.counter("serve_evictions_total").value == 1


def test_obs_legacy_trace_derived():
    o = obs.Obs()
    o.step_recorded("prefill", (0,), 8, 0.0, 1.0)
    o.step_recorded("decode", (0, 1), 2, 1.0, 1.5, lanes=4)
    assert o.legacy_trace() == [("prefill", (0,), 8), ("decode", (0, 1), 2)]
    assert o.steps[1].wall_s == pytest.approx(0.5)
    o.reset()
    assert o.legacy_trace() == []


def test_obs_disabled_keeps_steps_skips_registry():
    o = obs.Obs(enabled=False)
    o.request_enqueued(0)
    o.step_recorded("decode", (0,), 1, 0.0, 0.1, lanes=4)
    o.token_emitted(0)
    o.request_finished(0)
    assert len(o.steps) == 1  # pipeline-model input survives
    assert o.registry.families() == []  # no metric work
    assert o.finished == []


# ------------------------------------------------------------------- slo

def test_slo_pass_fail_and_violations():
    reqs = []
    for i in range(10):
        r = obs.RequestMetrics(rid=i, t_enqueue=0.0)
        r.t_first_token = 0.010 if i else 0.500  # one slow outlier
        r.token_times = [r.t_first_token, r.t_first_token + 0.002]
        r.t_finish = r.token_times[-1]
        reqs.append(r)
    ok = obs.evaluate_slo(reqs, obs.SLOTargets(ttft_p99_s=1.0))
    assert ok["pass"] is True and ok["violations"]["ttft_over_p99_target"] == 0
    bad = obs.evaluate_slo(reqs, obs.SLOTargets(ttft_p99_s=0.1))
    assert bad["pass"] is False
    assert bad["violations"]["ttft_over_p99_target"] == 1
    assert bad["checks"]["ttft_p99_s"]["ok"] is False


def test_slo_no_samples_is_indeterminate_not_failing():
    res = obs.evaluate_slo([], obs.SLOTargets(ttft_p99_s=0.1))
    assert res["checks"]["ttft_p99_s"]["ok"] is None
    assert res["pass"] is True


# -------------------------------------------------------- kernel profiling

def test_profiled_call_eager_capture():
    o = obs.Obs(profile=True)
    out = obs.profiled_call("k", o, lambda: jnp.ones((4,)) * 2)
    assert float(out[0]) == 2.0
    calls = o.registry.counter(
        "kernel_calls_total", labels={"kernel": "k", "mode": "eager"}
    )
    assert calls.value == 1
    wall = o.registry.histogram("kernel_wall_seconds",
                                labels={"kernel": "k"})
    assert wall.count == 1 and wall.sum > 0


def test_profiled_call_traced_counts_only():
    o = obs.Obs(profile=True)

    @jax.jit
    def f(x):
        return obs.profiled_call("k2", o, lambda: x * 2)

    f(jnp.ones((4,)))
    calls = o.registry.counter(
        "kernel_calls_total", labels={"kernel": "k2", "mode": "traced"}
    )
    assert calls.value == 1
    # no wall capture inside a trace: blocking a tracer is impossible
    wall = o.registry.histogram("kernel_wall_seconds",
                                labels={"kernel": "k2"})
    assert wall.count == 0


def test_profiled_call_without_obs_is_passthrough():
    assert float(obs.profiled_call("k3", None, lambda: jnp.float32(7))) == 7


# ------------------------------------------------------------- fidelity

def test_sqnr_reexport_compat():
    from repro.core.metrics import sqnr_db as legacy
    from repro.obs import sqnr_db

    assert legacy is sqnr_db
    assert sqnr_db([1.0, 2.0], [1.0, 2.0]) > 200  # exact match -> cap
    assert sqnr_db([1.0, 0.0], [0.0, 0.0]) == pytest.approx(
        10 * math.log10(0.5 / 0.5)
    )
