"""Chunked-prefill + prefix-cache guarantees.

The crux check is *bitwise reuse correctness*: with the prefix cache on,
a shared-system-prompt workload must produce token-for-token the outputs
of the cache-off run — across the float, mxfp4 and cim backends and both
KV pool layouts — while actually hitting (nonzero hit rate, fewer
prefill steps). Reuse rests on the causality argument in
``repro/serving/prefix.py``: chunk-aligned hits, pages zeroed beyond the
copied prefix at admission, and the first live suffix chunk recomputing
the page's quantized mirrors make a cache-on pool state bitwise a
cache-off one.

Plus the chunked-prefill path itself (fixed ``[1, chunk_len]`` windows
over prompts longer than ``prefill_len``) against greedy full-sequence
``lm.forward``, content-addressable fingerprint behaviour (determinism
across donors, corruption -> counted verify-failure miss), and a
property test over the host-side control plane (scheduler + refcounted
allocator + radix tree) under random interleavings.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs as C
from repro.core import cim as cimlib
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, lm
from repro.obs import Obs
from repro.serving import Engine, EngineConfig, PrefixCache
from repro.serving.kvcache import PoolExhausted, SlotAllocator
from repro.serving.prefix import page_fingerprint
from repro.serving.scheduler import Scheduler

CFG = C.tiny(C.ARCHS["starcoder2-7b"])
SYS = [5, 6, 7, 8, 9, 10, 11, 12]  # 8-token shared system prompt


@pytest.fixture(scope="module")
def float_model():
    params, _ = lm.init_model(jax.random.PRNGKey(0), CFG)
    return params, RunCtx(shd=ShardingCtx(), dense_attn_max=256)


@pytest.fixture(scope="module")
def mxfp4_model(float_model):
    params, ctx = float_model
    return (
        convert_params_mxfp4(params),
        dataclasses.replace(ctx, quant="mxfp4_wonly"),
    )


@pytest.fixture(scope="module")
def cim_model(float_model):
    params, ctx = float_model
    cim_cfg = cimlib.CIMConfig()
    batches = calibrate.calibration_batches(CFG, n_batches=2, batch=2, seq=16)
    conv, _ = calibrate.convert_model_cim(
        params, CFG, ctx, batches, cim_cfg=cim_cfg, min_n=32
    )
    return conv, dataclasses.replace(ctx, quant="cim", cim=cim_cfg)


def _engine(params, ctx, obs_on=False, **kw):
    base = dict(lanes=3, num_slots=4, page_len=24, prefill_len=8,
                policy="chunked", chunk_len=4)
    base.update(kw)
    return Engine(params, CFG, ctx, EngineConfig(**base),
                  obs=Obs(enabled=obs_on))


def _ref_greedy(params, ctx, prompt, max_new):
    toks = list(prompt)
    outs = []
    for _ in range(max_new):
        logits, _ = lm.forward(params, CFG, ctx, {"ids": jnp.asarray([toks])})
        t = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
        outs.append(t)
        toks.append(t)
    return outs


def _shared_prompts(n=5):
    """Most prompts open with the shared system prompt; one doesn't."""
    return [SYS + [20 + i] for i in range(n)] + [[3, 4, 5]]


def _run(params, ctx, prompts, max_new=4, **kw):
    eng = _engine(params, ctx, **kw)
    rids = [eng.add_request(list(p), max_new=max_new) for p in prompts]
    out = eng.run()
    return eng, [out[r] for r in rids]


# ------------------------------------------------- chunked prefill fidelity

@pytest.mark.parametrize("backend", ["float", "mxfp4"])
def test_chunked_prefill_matches_greedy(backend, float_model, mxfp4_model):
    """Fixed [1, chunk_len] prefill windows — including prompts longer
    than prefill_len, which the single-shot engine cannot admit at all —
    reproduce greedy full-sequence lm.forward token-for-token."""
    params, ctx = float_model if backend == "float" else mxfp4_model
    rng = np.random.default_rng(11)
    prompts = [
        rng.integers(0, CFG.vocab_size, size=n).tolist()
        for n in (3, 7, 8, 13, 20)  # straddle chunk and prefill_len edges
    ]
    _, outs = _run(params, ctx, prompts, max_new=4,
                   page_len=32, num_slots=4)
    for p, got in zip(prompts, outs):
        assert got == _ref_greedy(params, ctx, p, 4), f"len {len(p)}"


def test_single_shot_rejects_long_prompt_chunked_accepts(float_model):
    params, ctx = float_model
    long_prompt = list(range(1, 15))  # 14 > prefill_len=8
    eng = Engine(params, CFG, ctx,
                 EngineConfig(lanes=1, num_slots=1, page_len=24,
                              prefill_len=8))
    with pytest.raises(ValueError, match="prompt length"):
        eng.add_request(long_prompt, max_new=2)
    eng_c = _engine(params, ctx, lanes=1, num_slots=1)
    rid = eng_c.add_request(long_prompt, max_new=2)
    assert len(eng_c.run()[rid]) == 2


# ------------------------------------------------ bitwise prefix-cache reuse

@pytest.mark.parametrize("backend", ["float", "mxfp4", "cim"])
def test_prefix_cache_outputs_bitwise_equal(backend, float_model,
                                            mxfp4_model, cim_model):
    """Acceptance crux: shared-system-prompt workload, cache-on outputs
    token-identical to cache-off, with a nonzero hit rate — per quant
    backend (reused pages carry quantized-resident mirrors under
    mxfp4/cim, so byte-identical KV is what's being proven)."""
    params, ctx = {"float": float_model, "mxfp4": mxfp4_model,
                   "cim": cim_model}[backend]
    prompts = _shared_prompts(4 if backend == "cim" else 5)
    _, off = _run(params, ctx, prompts, prefix_cache=False)
    eng, on = _run(params, ctx, prompts, prefix_cache=True)
    assert on == off
    st_ = eng.prefix_stats()
    assert st_["hits"] > 0 and st_["hit_tokens"] > 0, st_
    assert st_["verify_failures"] == 0


def test_prefix_cache_parity_fused_layout(float_model):
    params, ctx = float_model
    prompts = _shared_prompts(4)
    _, off = _run(params, ctx, prompts, prefix_cache=False,
                  kv_layout="fused")
    eng, on = _run(params, ctx, prompts, prefix_cache=True,
                   kv_layout="fused")
    assert on == off and eng.prefix_stats()["hits"] > 0


def test_prefix_hits_reduce_prefill_steps(float_model):
    """The deterministic TTFT proxy: cache-on runs strictly fewer
    prefill-chunk steps on a shared-prefix workload (each hit skips
    n_tokens/chunk_len windows)."""
    params, ctx = float_model
    prompts = _shared_prompts(5)

    def prefills(pc):
        eng, _ = _run(params, ctx, prompts, prefix_cache=pc, obs_on=True)
        return sum(1 for e in eng.obs.steps if e.kind == "prefill")

    n_off, n_on = prefills(False), prefills(True)
    # 4 hit requests x 8 shared tokens / chunk_len 4 = 8 skipped windows
    assert n_on == n_off - 8, (n_on, n_off)


# ------------------------------------------- content-addressable identity

def test_fingerprint_deterministic_across_donors(float_model):
    """Two independently prefilled pages with the same prompt prefix
    fingerprint identically over the shared rows (content addressing),
    and differently once their suffixes are included."""
    params, ctx = float_model
    eng = _engine(params, ctx, lanes=2, num_slots=4)
    pa = SYS + [101, 102]
    pb = SYS + [201, 202]
    # max_new keeps both live until the second admits, so the LIFO
    # allocator cannot recycle the first page into the second request
    ra = eng.add_request(pa, max_new=6)
    rb = eng.add_request(pb, max_new=6)
    while eng.requests[rb].slot < 0:  # -1 until admitted
        eng.step()
    sa, sb = eng.requests[ra].slot, eng.requests[rb].slot
    assert sa != sb
    eng.run()  # retired, but nothing reused the pages yet
    n = len(SYS)
    assert page_fingerprint(eng.kv, sa, n) == page_fingerprint(eng.kv, sb, n)
    full = len(pa)
    assert (page_fingerprint(eng.kv, sa, full)
            != page_fingerprint(eng.kv, sb, full))


def test_fingerprint_corruption_is_counted_miss(float_model):
    """Bit-rot under an advertised page turns into a verify-failure miss
    that drops the backing slot — never silent wrong KV."""
    params, ctx = float_model
    eng = _engine(params, ctx, prefix_cache=True)
    rid = eng.add_request(SYS + [42], max_new=2)
    eng.run()
    donor = eng.requests[rid].slot
    assert donor in eng.prefix.cached_slots
    probe = SYS + [43]
    assert eng.prefix.match(probe, eng.kv) is not None
    # flip the donor page's raw K bytes in the pool
    for seg, spec in zip(eng.kv.pool, eng.kv.specs):
        if "k" in seg:
            ax = spec["k"].index("batch")
            idx = (slice(None),) * ax + (donor,)
            seg["k"] = seg["k"].at[idx].add(jnp.asarray(1, seg["k"].dtype))
    before = eng.prefix.stats()["verify_failures"]
    assert eng.prefix.match(probe, eng.kv) is None
    st_ = eng.prefix.stats()
    assert st_["verify_failures"] == before + 1
    assert donor not in eng.prefix.cached_slots  # backing dropped
    assert eng.kv.allocator.refcount(donor) == 0  # slot back on free list


# ------------------------------------------------ eviction + refcount unit

def test_prefix_eviction_respects_refcounts():
    """LRU eviction only ever frees pages the cache solely owns; pages a
    live request still references are pinned (refcount > 1)."""
    a = SlotAllocator(4)
    pc = PrefixCache(chunk_len=2, allocator=a, fingerprints=False)
    s_live, s_old, s_new = a.alloc(), a.alloc(), a.alloc()
    assert pc.insert([1, 2, 3, 4], s_live)  # cache takes its own ref
    assert pc.insert([5, 6], s_old)
    assert pc.insert([7, 8], s_new)
    a.free(s_old)  # donors' requests retire...
    a.free(s_new)
    # ...but s_live's request is still running -> not evictable
    assert pc.n_evictable == 2
    assert pc.evict_lru()  # LRU order: s_old went in before s_new
    assert a.refcount(s_old) == 0 and pc.match([5, 6, 9]) is None
    assert pc.evict_lru()
    assert a.refcount(s_new) == 0
    assert not pc.evict_lru()  # s_live is pinned by its request
    assert pc.match([1, 2, 3]) is not None  # still served
    a.free(s_live)
    assert pc.n_evictable == 1 and pc.evict_lru()
    assert a.num_free == 4  # every reference drained


def test_prefix_match_always_leaves_live_suffix():
    """A fully cached prompt still matches at most len-1 tokens: the
    admitted request must emit its first token from a real chunk."""
    a = SlotAllocator(2)
    pc = PrefixCache(chunk_len=2, allocator=a, fingerprints=False)
    s = a.alloc()
    pc.insert([1, 2, 3, 4], s)
    hit = pc.match([1, 2, 3, 4])
    assert hit is not None and hit.n_tokens == 2  # not 4
    assert pc.match([1, 2]) is None  # would leave nothing live
    hit = pc.match([1, 2, 3, 4, 5])
    assert hit.n_tokens == 4


def test_prefix_insert_keeps_existing_backing():
    a = SlotAllocator(3)
    pc = PrefixCache(chunk_len=2, allocator=a, fingerprints=False)
    s1, s2 = a.alloc(), a.alloc()
    assert pc.insert([1, 2, 3, 4], s1)
    # same prefix from a second donor: nodes keep s1, s2 is not adopted
    assert not pc.insert([1, 2, 3, 4], s2)
    assert pc.match([1, 2, 3, 4, 5]).slot == s1
    assert a.refcount(s2) == 1  # only its request's own reference


# --------------------------------------- control-plane property (invariants)

_CHUNK, _SLOTS, _LANES = 2, 3, 2


def _sim_step(rng, sched, alloc, cache, live):
    """One scheduler-planned unit of fake work, mirroring the engine's
    chunked admission/retire flow without any device compute."""
    action = sched.plan(alloc.num_free + cache.n_evictable)
    if action == "idle":
        return
    if action == "prefill":
        req = sched.prefilling
        if req is None:
            nxt = sched.waiting[0]
            hit = cache.match(nxt.prompt)
            if hit is not None:
                alloc.retain(hit.slot)  # pin the donor
            try:
                slot = alloc.try_alloc()
                while slot is None:
                    if not cache.evict_lru():
                        raise PoolExhausted("planned admit with no slot")
                    slot = alloc.try_alloc()
            finally:
                if hit is not None:
                    alloc.release(hit.slot)
            req = sched.begin_prefill(slot, step=0)
            live[req.rid] = req
            if hit is not None:
                req.prefilled = req.prefix_hit = hit.n_tokens
        req.prefilled = min(len(req.prompt), req.prefilled + _CHUNK)
        if req.prefilled == len(req.prompt):
            sched.finish_prefill(req)
            req.out.append(rng.randrange(100))
            cache.insert(req.prompt, req.slot)
    else:  # decode: every running request advances one token
        for req in list(sched.running.values()):
            req.out.append(rng.randrange(100))
            req.pos += 1
            if Scheduler.stop_reason(req, page_len=64) is not None:
                sched.finish(req, step=0)
                alloc.free(req.slot)
                del live[req.rid]


def _check_invariants(sched, alloc, cache, live):
    # lane -> slot stays injective across running + mid-prefill requests
    holders = list(sched.running.values())
    if sched.prefilling is not None:
        holders.append(sched.prefilling)
    slots = [r.slot for r in holders]
    lanes = [r.lane for r in holders]
    assert len(set(slots)) == len(slots), f"slot aliasing: {slots}"
    assert len(set(lanes)) == len(lanes), f"lane aliasing: {lanes}"
    # every live holder's slot is allocated; refcount covers all owners
    for r in holders:
        assert alloc.refcount(r.slot) >= 1
    for s in cache.cached_slots:
        assert alloc.refcount(s) >= 1, "cache advertises a freed slot"
    # free + allocated partition the pool exactly
    assert alloc.num_free + len(alloc.in_use) == _SLOTS
    expected = {r.slot for r in holders} | cache.cached_slots
    assert alloc.in_use == expected, (alloc.in_use, expected)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_control_plane_invariants_under_random_interleaving(seed):
    """Property: under random arrival/step interleavings of the real
    Scheduler + refcounted SlotAllocator + PrefixCache (fingerprints
    off — pure control plane), slots are never aliased across lanes,
    nothing is double-freed, the cache never outlives its references,
    and every refcount drains to zero once the system quiesces."""
    rng = random.Random(seed)
    from repro.serving.scheduler import Request

    sched = Scheduler(lanes=_LANES, policy="chunked")
    alloc = SlotAllocator(_SLOTS)
    cache = PrefixCache(chunk_len=_CHUNK, allocator=alloc,
                        fingerprints=False)
    live, rid = {}, 0
    for _ in range(60):
        if rng.random() < 0.4 and len(sched.waiting) < 4:
            # small alphabet + even lengths make prefixes collide often
            n = rng.choice([2, 4, 6])
            prompt = [rng.randrange(3) for _ in range(n)]
            sched.add(Request(rid=rid, prompt=prompt,
                              max_new=rng.randint(1, 4)))
            rid += 1
        else:
            _sim_step(rng, sched, alloc, cache, live)
        _check_invariants(sched, alloc, cache, live)
    while sched.has_work:  # drain
        _sim_step(rng, sched, alloc, cache, live)
        _check_invariants(sched, alloc, cache, live)
    assert not live and sched.running == {} and sched.prefilling is None
    while cache.evict_lru():  # cache holds the only remaining references
        pass
    assert alloc.num_free == _SLOTS and alloc.in_use == set()
