"""Fused quantized hot path invariants.

Three claim families from the perf rework:

- the bit-twiddle (IEEE-754 exponent-field) quantizer is *exactly* the
  OCP MX rule — verified bitwise against a float64 correctly-rounded
  floor(log2) reference across grid-boundary ties, one-ulp binade edges,
  zero blocks and E8M0 clamp edges (``jnp.log2`` itself is not correctly
  rounded there, which is why the reference is f64);
- Pallas kernels match the jnp reference at odd, non-tile-aligned shapes
  (ViT's M=197/145, non-multiple-of-128 N) through the pad-M-up wrappers;
- the quantized-resident KV cache decodes bitwise identically to the
  requant-per-step reference for both K and V, including ring wrap and
  partial trailing V blocks, while doing O(1) quantize work per step.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core import cim as cimlib
from repro.core import mx as mxlib
from repro.kernels.cim_linear import ops as cim_ops
from repro.kernels.mxfp4_matmul import ops as mm_ops
from repro.kernels.mxfp4_matmul import ref as mm_ref
from repro.layers import attention as attn_mod
from repro.layers.common import RunCtx, ShardingCtx


# --------------------------------------------- bit-twiddle quantizer ==


def _quantize_ref_f64(x: np.ndarray):
    """Correctly-rounded OCP MX reference: float64 floor(log2) for the
    shared exponent and the local E2M1 binade, numpy rint (ties-to-even).
    Subnormal f32 inputs are flushed to zero first — XLA CPU multiplies
    flush them, and the jnp quantizer inherits that (pre-existing)
    behavior; everything normal is exact."""
    x = np.asarray(x, np.float32)
    x = np.where(np.abs(x) < np.float32(2.0**-126), np.float32(0.0), x)
    pad = (-x.shape[-1]) % 32
    if pad:
        x = np.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = x.reshape(x.shape[:-1] + (x.shape[-1] // 32, 32)).astype(np.float64)
    amax = np.abs(xb).max(-1)
    with np.errstate(divide="ignore"):
        e = np.floor(np.log2(np.where(amax > 0, amax, 1.0))) - 2
    e = np.where(amax > 0, e, -127)
    e = np.clip(e, -127, 127)
    y = xb * 2.0 ** (-e[..., None])
    ay = np.abs(y)
    with np.errstate(divide="ignore"):
        ee = np.clip(np.floor(np.log2(np.maximum(ay, 1e-300))), 0, 2)
    step = 2.0 ** (ee - 1)
    q = np.minimum(np.rint(ay / step) * step, 6.0)
    codes = (np.sign(y) * 2 * q).reshape(x.shape).astype(np.int8)
    return codes, e.astype(np.int8)


def _assert_matches_ref(x: np.ndarray):
    mx = mxlib.quantize(jnp.asarray(x))
    rc, re = _quantize_ref_f64(x)
    np.testing.assert_array_equal(np.asarray(mx.codes), rc)
    np.testing.assert_array_equal(np.asarray(mx.exps), re)


def test_bit_twiddle_quantizer_random_blocks():
    rng = np.random.default_rng(0)
    for scale in (1.0, 1e-3, 1e3, 1e30, 1e-30):
        _assert_matches_ref(
            rng.standard_normal((16, 96)).astype(np.float32) * scale
        )


def test_bit_twiddle_quantizer_grid_ties():
    """Tie points of every E2M1 binade, swept across block scales —
    ties-to-even on the local grid."""
    ties = np.array([0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, 7.0], np.float32)
    rng = np.random.default_rng(1)
    for e in (-20, -2, 0, 3, 19):
        row = np.tile(ties, 4) * np.float32(2.0**e)
        # anchor amax so the shared scale is exact and ties stay ties
        row[0] = 6.0 * 2.0**e
        _assert_matches_ref(row[None])
    # random sign patterns over tie values
    x = rng.choice(ties, size=(8, 32)) * rng.choice([-1.0, 1.0], (8, 32))
    x[:, 0] = 6.0
    _assert_matches_ref(x.astype(np.float32))


def test_bit_twiddle_quantizer_binade_edges():
    """amax one f32-ulp below a power of two: jnp.log2 rounds *up* there
    (measured), so a log2-based floor skips the OCP clamp-at-6; the
    exponent-field quantizer must take the f64-exact branch."""
    below = np.nextafter(np.float32(4.0), np.float32(0.0))
    x = np.zeros((3, 32), np.float32)
    x[0, 0] = below
    x[1, 0] = 4.0
    x[2, 0] = np.nextafter(np.float32(4.0), np.float32(8.0))
    _assert_matches_ref(x)
    # the edge case really clamps: amax scales to just under 8 -> code 12
    mx = mxlib.quantize(jnp.asarray(x))
    assert int(mx.codes[0, 0]) == 12 and int(mx.exps[0, 0]) == -1


def test_bit_twiddle_quantizer_zero_and_clamp_edges():
    rng = np.random.default_rng(2)
    zero = np.zeros((2, 64), np.float32)
    _assert_matches_ref(zero)
    np.testing.assert_array_equal(
        np.asarray(mxlib.quantize(jnp.asarray(zero)).exps),
        np.full((2, 2), mxlib.E8M0_MIN, np.int8),
    )
    # E8M0 clamp edges: largest finite f32 binade (e = 125; the +127 cap
    # is reachable only through inf, where behavior is undefined) and the
    # subnormal floor (e clamps at -127)
    huge = (rng.uniform(0.5, 2.0, (4, 32)).astype(np.float32)
            * np.float32(1.5e38)
            * rng.choice([-1.0, 1.0], (4, 32)).astype(np.float32))
    _assert_matches_ref(huge)
    assert int(mxlib.quantize(jnp.asarray(huge)).exps.max()) == 125
    tiny = rng.standard_normal((4, 32)).astype(np.float32) * np.float32(2e-38)
    _assert_matches_ref(tiny)


def test_fake_quant_paths_consistent():
    """fake_quant (fused) == dequantize(quantize(x)); fake_quant_axis
    (in-layout) == moveaxis composition. Bitwise."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 48, 4, 16)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(mxlib.fake_quant(x)),
        np.asarray(mxlib.dequantize(mxlib.quantize(x), out_len=16)),
    )
    np.testing.assert_array_equal(
        np.asarray(mxlib.fake_quant_axis(x, 1)),
        np.asarray(
            jnp.moveaxis(mxlib.fake_quant(jnp.moveaxis(x, 1, -1)), -1, 1)
        ),
    )


def test_quantize_axis_code_entry_point():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 64, 8)).astype(np.float32))
    mx = mxlib.quantize_axis(x, 1)  # quantized axis moved last
    ref = mxlib.quantize(jnp.moveaxis(x, 1, -1))
    np.testing.assert_array_equal(np.asarray(mx.codes), np.asarray(ref.codes))
    np.testing.assert_array_equal(np.asarray(mx.exps), np.asarray(ref.exps))


# ------------------------------------------------ odd-shape kernels ==


@pytest.mark.parametrize("m,k,n", [(197, 64, 96), (145, 96, 48), (34, 64, 80)])
def test_mxfp4_kernel_odd_shapes(m, k, n):
    """Pad-M-up wrapper: ViT's M=197/145 and non-multiple-of-128 N."""
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n))
    x = jax.random.normal(kx, (m, k), jnp.bfloat16)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    wq = mxlib.quantize_w(w)
    codes = mxlib.pack_codes(wq.codes.T).T
    exps = mxlib.exps_to_biased(wq.exps)
    out = mm_ops.mxfp4_matmul(x, codes, exps, interpret=True)
    ref = mm_ref.mxfp4_matmul_ref(x, codes, exps)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2 * np.abs(np.asarray(ref, np.float32)).max(),
    )


@pytest.mark.parametrize("m,k,n", [(197, 64, 96), (145, 96, 48)])
def test_cim_kernel_fused_quantize_odd_shapes(m, k, n):
    """The fused-quantize CIM kernel (raw activations in) matches the jnp
    simulation at odd M and non-128 N."""
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n + 1))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    wq = mxlib.quantize_w(w)
    cfg = cimlib.CIMConfig()
    calib = cimlib.calibrate_rowhist([x], wq, cfg)
    out = cim_ops.cim_linear(x, wq, calib, cfg=cfg, interpret=True)
    ref, _ = cimlib.cim_linear(x, wq, cfg, calib)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


def test_pick_bm_never_degenerate():
    from repro.kernels.mxfp4_matmul.ops import pick_bm

    assert pick_bm(197) == 128  # pads up to 2 tiles, full-width tile
    assert pick_bm(6) == 16  # pads up, never a 6-row tile
    assert pick_bm(1024) == 128


# -------------------------------------------- impl/interpret dispatch ==


def test_interpret_default_is_platform_derived():
    from repro.kernels import default_interpret

    ctx = RunCtx(shd=ShardingCtx())
    assert ctx.interpret == default_interpret()
    # Mosaic/TPU kernels: interpreted everywhere except real TPUs
    assert default_interpret() == (jax.default_backend() != "tpu")


def test_impl_auto_dispatch():
    ctx = RunCtx(shd=ShardingCtx())
    assert ctx.impl == "auto"
    assert ctx.use_pallas == (jax.default_backend() == "tpu")
    assert dataclasses.replace(ctx, impl="pallas").use_pallas
    assert not dataclasses.replace(ctx, impl="jnp").use_pallas


# --------------------------------------- quantized-resident KV decode ==


def _decode_ref_vs_resident(W, steps, pre, seed=0):
    """Drive attn_apply's decode branch with and without the resident
    code mirrors from identical inputs; returns per-step outputs."""
    cfg = attn_mod.AttnStatic(
        d_model=64, n_heads=4, n_kv=2, head_dim=32, use_rope=False
    )
    key = jax.random.PRNGKey(seed)
    p, _ = attn_mod.attn_init(key, cfg)
    ctx = RunCtx(shd=ShardingCtx(), quant="cim", dense_attn_max=256)
    assert ctx.hybrid_digital_sdpa
    b = 2
    ref_cache = attn_mod.attn_cache_init(cfg, b, W, mx_digital=False)
    res_cache = attn_mod.attn_cache_init(cfg, b, W, mx_digital=True)
    # prefill-into-cache populates both (quantized mirrors on the resident)
    x0 = jax.random.normal(jax.random.fold_in(key, 1), (b, pre, 64),
                           jnp.bfloat16)
    pos0 = jnp.broadcast_to(jnp.arange(pre)[None], (b, pre))
    y_r, ref_cache = attn_mod.attn_apply(ctx, cfg, p, x0, pos0, ref_cache)
    y_q, res_cache = attn_mod.attn_apply(ctx, cfg, p, x0, pos0, res_cache)
    np.testing.assert_array_equal(
        np.asarray(y_r, np.float32), np.asarray(y_q, np.float32)
    )
    outs = []
    for t in range(steps):
        xt = jax.random.normal(jax.random.fold_in(key, 100 + t), (b, 1, 64),
                               jnp.bfloat16)
        post = jnp.full((b, 1), pre + t)
        pos = jnp.full((b,), pre + t, jnp.int32)
        y_r, ref_cache = attn_mod.attn_apply(ctx, cfg, p, xt, post,
                                             ref_cache, pos)
        y_q, res_cache = attn_mod.attn_apply(ctx, cfg, p, xt, post,
                                             res_cache, pos)
        outs.append((np.asarray(y_r, np.float32),
                     np.asarray(y_q, np.float32)))
    return outs, ref_cache, res_cache


def test_resident_kv_decode_bitwise_matches_requant():
    """Resident K codes + active-block V requant == full requant-per-step,
    bitwise, at every step — including a partial trailing V block
    (W=48)."""
    outs, ref_cache, res_cache = _decode_ref_vs_resident(W=48, steps=10,
                                                        pre=5)
    for t, (r, q) in enumerate(outs):
        np.testing.assert_array_equal(r, q, err_msg=f"step {t}")
    # the resident mirrors decode to exactly the raw cache's quantization
    kd_ref = mxlib.fake_quant(ref_cache["k"].astype(jnp.float32))
    kd_res = mxlib.dequantize(
        mxlib.MX(res_cache["k_codes"], res_cache["k_exps"]), out_len=32
    )
    np.testing.assert_array_equal(np.asarray(kd_ref), np.asarray(kd_res))
    vd_ref = mxlib.fake_quant_axis(ref_cache["v"].astype(jnp.float32), 1)
    vd_res = jnp.moveaxis(
        mxlib.dequantize(
            mxlib.MX(res_cache["v_codes"], res_cache["v_exps"]), out_len=48
        ),
        -1, 1,
    )
    np.testing.assert_array_equal(np.asarray(vd_ref), np.asarray(vd_res))


def test_resident_kv_decode_bitwise_through_ring_wrap():
    """Ring wrap (pos >= W) rewrites old rows/blocks; the resident update
    must requantize exactly the touched K row and V block."""
    outs, _, _ = _decode_ref_vs_resident(W=32, steps=40, pre=3)
    for t, (r, q) in enumerate(outs):
        np.testing.assert_array_equal(r, q, err_msg=f"step {t}")


def test_resident_pool_decode_matches_legacy_cache_lm():
    """Model-level: lm.decode_step over an mx_digital cache tree equals
    the legacy (requant-per-step) cache tree bitwise under the cim
    backend."""
    cfg = C.tiny(C.ARCHS["starcoder2-7b"])
    from repro.models import calibrate, lm

    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    ctx = RunCtx(shd=ShardingCtx(), dense_attn_max=256)
    batches = calibrate.calibration_batches(cfg, n_batches=1, batch=2,
                                            seq=8)
    conv, _ = calibrate.convert_model_cim(params, cfg, ctx, batches,
                                          min_n=32)
    hyb = dataclasses.replace(ctx, quant="cim")
    ids = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0,
                             cfg.vocab_size)
    legacy = lm.init_cache(cfg, 2, 16, mx_digital=False)
    resident = lm.init_cache(cfg, 2, 16, mx_digital=True)
    _, legacy = lm.forward(conv, cfg, hyb, {"ids": ids}, caches=legacy)
    _, resident = lm.forward(conv, cfg, hyb, {"ids": ids}, caches=resident)
    tok = ids[:, -1:]
    for t in range(4):
        lg_l, legacy = lm.decode_step(conv, cfg, hyb, tok, jnp.int32(6 + t),
                                      legacy)
        lg_r, resident = lm.decode_step(conv, cfg, hyb, tok,
                                        jnp.int32(6 + t), resident)
        np.testing.assert_array_equal(
            np.asarray(lg_l, np.float32), np.asarray(lg_r, np.float32),
            err_msg=f"step {t}",
        )
        tok = jnp.argmax(lg_l.astype(jnp.float32), -1)[:, None]
