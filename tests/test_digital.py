"""Digital-stage numerics (paper §4.4-4.5): MXFP4 attention with BF16
accumulation and FlashAttention-style deferred softmax."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import digital, mx as mxlib


def _qkv(seed, b=2, s=48, d=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, (b, s, d), jnp.float32) for k in ks)


def test_flash_softmax_equals_naive():
    """Streaming max/sum with deferred division == naive softmax (no
    quantization)."""
    q, k, v = _qkv(0)
    out = digital.mx_attention(q, k, v, causal=False, quantize_sv=False)
    # reference with the SAME quantized QK inputs
    qq = mxlib.fake_quant(q)
    kq = mxlib.fake_quant(k)
    s = jnp.einsum("bqd,bkd->bqk", qq, kq) * q.shape[-1] ** -0.5
    s = s.astype(jnp.bfloat16).astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bqk,bkd->bqd", p, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=3e-2, atol=3e-2
    )


def test_causal_mask_blocks_future():
    q, k, v = _qkv(1, s=16)
    out = digital.mx_attention(q, k, v, causal=True)
    # first query position attends only to key 0: output == v[0] (any scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32)[:, 0],
        np.asarray(mxlib.fake_quant_axis(v, -2))[:, 0],
        rtol=5e-2, atol=5e-2,
    )


def test_tile_size_invariance():
    q, k, v = _qkv(2, s=64)
    o1 = digital.mx_attention(q, k, v, tile=16, quantize_sv=False)
    o2 = digital.mx_attention(q, k, v, tile=64, quantize_sv=False)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        rtol=2e-2, atol=2e-2,
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_attention_fidelity_bound(seed):
    """MXFP4 attention stays within a sane error band of fp32 (the paper's
    near-digital-accuracy regime)."""
    q, k, v = _qkv(seed % 1000, s=32)
    out = np.asarray(digital.mx_attention(q, k, v, causal=True), np.float32)
    ref = np.asarray(digital.attention_ref(q, k, v, causal=True))
    rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
    assert rel < 0.35, rel  # FP4 operands: coarse but bounded
    assert np.all(np.isfinite(out))


def test_v_quantized_along_sequence():
    """V must be block-quantized along the SV contraction (sequence) axis
    (paper §3.3/§4.4) — check the helper quantizes the right axis."""
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 16))
    vq = mxlib.fake_quant_axis(v, axis=-2)
    # blocks of 32 along axis -2: scales shared across seq, not features
    q0 = mxlib.quantize(jnp.moveaxis(v, -2, -1))
    assert q0.exps.shape[-1] == 64 // 32 * 16 // 16  # sanity on block count
    assert vq.shape == v.shape
