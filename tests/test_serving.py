"""Serving-engine invariants.

The two crux checks:

- *Isolation*: continuous batching with staggered arrivals produces the
  same per-request completions as running each request alone — both
  against a second engine (same compiled steps => bit-identical lanes)
  and against greedy full-sequence ``lm.forward`` (same backend).
- *Pipeline fidelity*: the discrete-event FWS pipeline model's
  steady-state FPS reproduces the Table-7 figures for the paper's
  encoder shapes within 5%.

Plus the satellite decode-path guarantee: ``lm.decode_step`` over the
paged cache matches full-sequence ``lm.forward`` logits token-for-token
under the mxfp4 and cim backends.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core import cim as cimlib
from repro.hwmodel import perf, specs as S
from repro.layers import attention as attn_mod
from repro.layers.common import RunCtx, ShardingCtx, convert_params_mxfp4
from repro.models import calibrate, lm
from repro.serving import Engine, EngineConfig
from repro.serving import pipeline as pipe
from repro.serving.kvcache import (
    PagedKVCache,
    PoolExhausted,
    SlotAllocator,
    gather_rows,
    scatter_rows,
)
from repro.serving.scheduler import Request, Scheduler, static_batching_plan

CFG = C.tiny(C.ARCHS["starcoder2-7b"])  # full attention, dense


@pytest.fixture(scope="module")
def float_model():
    params, _ = lm.init_model(jax.random.PRNGKey(0), CFG)
    return params, RunCtx(shd=ShardingCtx(), dense_attn_max=256)


@pytest.fixture(scope="module")
def mxfp4_model(float_model):
    params, ctx = float_model
    return (
        convert_params_mxfp4(params),
        dataclasses.replace(ctx, quant="mxfp4_wonly"),
    )


@pytest.fixture(scope="module")
def cim_model(float_model):
    params, ctx = float_model
    cim_cfg = cimlib.CIMConfig()
    batches = calibrate.calibration_batches(CFG, n_batches=2, batch=2, seq=16)
    conv, _ = calibrate.convert_model_cim(
        params, CFG, ctx, batches, cim_cfg=cim_cfg, min_n=32
    )
    return conv, dataclasses.replace(ctx, quant="cim", cim=cim_cfg)


# ------------------------------------------------------------ unit pieces

def test_slot_allocator():
    a = SlotAllocator(3)
    got = [a.alloc() for _ in range(3)]
    assert sorted(got) == [0, 1, 2]
    # satellite regression: alloc() used to return None on exhaustion,
    # which flowed straight into the jitted step as a row index
    assert a.try_alloc() is None and a.num_free == 0
    with pytest.raises(PoolExhausted):
        a.alloc()
    a.free(got[1])
    assert a.num_free == 1 and a.alloc() == got[1]
    with pytest.raises(ValueError):
        a.free(99)


def test_slot_allocator_refcounts():
    a = SlotAllocator(2)
    s = a.alloc()
    assert a.refcount(s) == 1
    a.retain(s)
    assert a.refcount(s) == 2
    a.release(s)  # one owner left -> still allocated
    assert a.refcount(s) == 1 and s in a.in_use and a.num_free == 1
    a.release(s)  # last owner -> back on the free list
    assert a.refcount(s) == 0 and a.num_free == 2
    with pytest.raises(ValueError):
        a.release(s)  # double-free
    with pytest.raises(ValueError):
        a.retain(s)  # retain of a free slot


def test_paged_pool_gather_scatter_roundtrip():
    kv = PagedKVCache(CFG, num_slots=3, lanes=2, page_len=8)
    key = jax.random.PRNGKey(0)
    pool = []
    for seg in kv.pool:
        seg2 = {}
        for k, v in seg.items():
            key, sub = jax.random.split(key)
            seg2[k] = jax.random.normal(sub, v.shape, jnp.float32).astype(
                v.dtype
            )
        pool.append(seg2)
    rows = jnp.asarray([2, 0], jnp.int32)
    got = gather_rows(pool, kv.specs, rows)
    back = scatter_rows(pool, kv.specs, rows, got)
    for seg_a, seg_b in zip(pool, back):
        for k in seg_a:
            np.testing.assert_array_equal(np.asarray(seg_a[k]),
                                          np.asarray(seg_b[k]))
    # a scatter of fresh values lands on exactly the addressed rows
    fresh = jax.tree.map(lambda x: jnp.ones_like(x), got)
    out = scatter_rows(pool, kv.specs, rows, fresh)
    for seg_o, seg_p, spec in zip(out, pool, kv.specs):
        for k in seg_o:
            ax = spec[k].index("batch")
            o = np.moveaxis(np.asarray(seg_o[k]), ax, 0)
            p = np.moveaxis(np.asarray(seg_p[k]), ax, 0)
            assert (o[np.asarray(rows)] == 1).all()
            keep = [i for i in range(o.shape[0]) if i not in (0, 2)]
            np.testing.assert_array_equal(o[keep], p[keep])


def test_scatter_rows_rejects_lossy_dtype():
    """Regression: scatter used to silently ``.astype`` values into the
    pool dtype — f32 pages written into a bf16 pool lost mantissa bits
    with no signal. Lossy writes now raise; widening writes still pass."""
    kv = PagedKVCache(CFG, num_slots=2, lanes=1, page_len=8)
    rows = jnp.asarray([0], jnp.int32)
    good = gather_rows(kv.pool, kv.specs, rows)
    bad = jax.tree.map(lambda x: x.astype(jnp.float32)
                       if x.dtype == jnp.bfloat16 else x, good)
    with pytest.raises(TypeError, match="lossy"):
        scatter_rows(kv.pool, kv.specs, rows, bad)
    # same-dtype and widening (f16 -> f32 would promote) writes still work
    scatter_rows(kv.pool, kv.specs, rows, good)


def test_paged_pool_rejects_recurrent_and_narrow_window():
    with pytest.raises(NotImplementedError, match="attention-only"):
        PagedKVCache(C.tiny(C.ARCHS["zamba2-1.2b"]), 2, 2, 8)
    with pytest.raises(NotImplementedError, match="full pages"):
        PagedKVCache(C.tiny(C.ARCHS["h2o-danube-1.8b"]), 2, 2, 32)


def test_decode_vector_pos_matches_scalar(float_model):
    """Per-lane positions (all equal) are bitwise the scalar-pos decode."""
    params, ctx = float_model
    b, p = 2, 6
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, p), 0,
                                CFG.vocab_size)
    caches = lm.init_cache(CFG, b, 16)
    _, caches = lm.forward(params, CFG, ctx, {"ids": prompt}, caches=caches)
    ids = prompt[:, -1:]
    lg_s, _ = lm.decode_step(params, CFG, ctx, ids, jnp.int32(p), caches)
    lg_v, _ = lm.decode_step(
        params, CFG, ctx, ids, jnp.full((b,), p, jnp.int32), caches
    )
    np.testing.assert_array_equal(np.asarray(lg_s, np.float32),
                                  np.asarray(lg_v, np.float32))


def test_kv_pad_positions_never_attended(float_model):
    """Right-padded prefill with KV_PAD positions == unpadded prefill."""
    params, ctx = float_model
    ids = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 0, CFG.vocab_size)
    lg_ref, _ = lm.forward(params, CFG, ctx, {"ids": ids})
    pad_ids = jnp.pad(ids, ((0, 0), (0, 3)))
    positions = jnp.concatenate(
        [jnp.arange(5)[None], jnp.full((1, 3), attn_mod.KV_PAD)], axis=1
    )
    lg_pad, _ = lm.forward(
        params, CFG, ctx, {"ids": pad_ids, "positions": positions}
    )
    np.testing.assert_allclose(
        np.asarray(lg_pad[:, :5], np.float32),
        np.asarray(lg_ref, np.float32), rtol=0, atol=0,
    )


# -------------------------------------------------------------- scheduler

def _req(rid, n=4, max_new=3, **kw):
    return Request(rid=rid, prompt=list(range(1, n + 1)), max_new=max_new,
                   **kw)


def test_scheduler_policies_and_eviction():
    s = Scheduler(lanes=2, policy="prefill")
    assert s.plan(free_slots=3) == "idle"
    s.add(_req(0))
    s.add(_req(1))
    s.add(_req(2))
    assert s.plan(3) == "prefill"
    r0 = s.admit(slot=0, step=1)
    assert (r0.rid, r0.pos) == (0, 4) and s.num_active == 1
    assert s.plan(2) == "prefill"  # prefill-prioritized: fill the batch
    r1 = s.admit(slot=1, step=2)
    assert s.plan(1) == "decode"  # lanes full -> decode
    assert s.plan(0) == "decode"
    s.finish(r0, step=5)
    assert r0.done and s.num_active == 1
    assert s.plan(1) == "prefill"  # freed lane backfills immediately

    d = Scheduler(lanes=2, policy="decode")
    d.add(_req(0))
    d.add(_req(1))
    assert d.plan(2) == "prefill"  # nothing running yet
    d.admit(slot=0, step=1)
    assert d.plan(1) == "decode"  # decode-prioritized: never stall decodes
    with pytest.raises(ValueError):
        Scheduler(2, policy="fifo")


def test_stop_conditions():
    r = _req(0, n=4, max_new=2)
    r.pos = 4
    r.out = [7]
    assert not Scheduler.stopped(r, page_len=16)
    r.out = [7, 7]
    assert Scheduler.stopped(r, page_len=16)
    r2 = _req(1, n=4, max_new=8, stop_token=5)
    r2.out = [3, 5]
    assert Scheduler.stopped(r2, page_len=16)
    r3 = _req(2, n=4, max_new=100)
    r3.out = [1]
    r3.pos = 16
    assert Scheduler.stopped(r3, page_len=16)  # page exhausted


# ------------------------------------------------- continuous batching ==

def _ref_greedy(params, ctx, prompt, max_new):
    toks = list(prompt)
    outs = []
    for _ in range(max_new):
        logits, _ = lm.forward(params, CFG, ctx, {"ids": jnp.asarray([toks])})
        t = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
        outs.append(t)
        toks.append(t)
    return outs


def _staggered_run(params, ctx, reqs, policy="prefill"):
    ecfg = EngineConfig(lanes=3, num_slots=4, page_len=24, prefill_len=8,
                        policy=policy)
    eng = Engine(params, CFG, ctx, ecfg)
    rids = []
    for i, (prompt, max_new) in enumerate(reqs):
        rids.append(eng.add_request(prompt, max_new=max_new))
        eng.step()  # arrivals interleave with engine progress
        if i % 2:
            eng.step()
    return eng, {r: eng.requests[r] for r in rids}, eng.run()


@pytest.mark.parametrize("backend", ["float", "mxfp4"])
def test_continuous_batching_matches_single_request(
    backend, float_model, mxfp4_model
):
    params, ctx = float_model if backend == "float" else mxfp4_model
    rng = np.random.default_rng(3)
    reqs = [
        (rng.integers(0, CFG.vocab_size, size=rng.integers(2, 9)).tolist(),
         int(rng.integers(2, 7)))
        for _ in range(6)
    ]
    eng, _, out = _staggered_run(params, ctx, reqs)
    assert eng.slot_utilization > 0.5
    # (a) same compiled steps, one request at a time -> bit-identical lanes
    solo = Engine(params, CFG, ctx, eng.ecfg)
    for rid, (prompt, max_new) in enumerate(reqs):
        srid = solo.add_request(prompt, max_new=max_new)
        assert solo.run()[srid] == out[rid], f"lane isolation broke rid {rid}"
    # (b) greedy full-sequence lm.forward, same backend
    for rid, (prompt, max_new) in enumerate(reqs):
        assert _ref_greedy(params, ctx, prompt, max_new) == out[rid], (
            f"decode path diverged from lm.forward for rid {rid}"
        )


def test_continuous_batching_isolation_cim(cim_model):
    """Under the hybrid analog backend, staggered continuous batching is
    still bit-identical to solo runs through the same compiled steps
    (lanes are independent; fixed shapes -> one executable). The greedy
    lm.forward cross-check is omitted for cim: cross-graph 1-ulp ties
    flip MXFP4/INT5 codes (see test_backends.py docstring)."""
    params, ctx = cim_model
    rng = np.random.default_rng(5)
    reqs = [
        (rng.integers(0, CFG.vocab_size, size=rng.integers(2, 9)).tolist(),
         int(rng.integers(2, 6)))
        for _ in range(3)
    ]
    eng, _, out = _staggered_run(params, ctx, reqs)
    solo = Engine(params, CFG, ctx, eng.ecfg)
    for rid, (prompt, max_new) in enumerate(reqs):
        srid = solo.add_request(prompt, max_new=max_new)
        assert solo.run()[srid] == out[rid], f"lane isolation broke rid {rid}"


def test_decode_priority_policy_runs(float_model):
    params, ctx = float_model
    rng = np.random.default_rng(4)
    reqs = [
        (rng.integers(0, CFG.vocab_size, size=5).tolist(), 3)
        for _ in range(4)
    ]
    _, _, out = _staggered_run(params, ctx, reqs, policy="decode")
    for rid, (prompt, max_new) in enumerate(reqs):
        assert _ref_greedy(params, ctx, prompt, max_new) == out[rid]


# ----------------------------------- satellite: admission regression fixes

def test_page_exhaustion_evicts_and_readmits(float_model):
    """Satellite regression: ``add_request`` used to reject any request
    with ``len(prompt) + max_new > page_len`` up front, which made the
    scheduler's "page_exhausted" stop arm dead code. The page budget is
    runtime state now: the request decodes until its page fills, finishes
    with reason page_exhausted, and its freed slot re-admits the next
    waiting request."""
    params, ctx = float_model
    ecfg = EngineConfig(lanes=1, num_slots=1, page_len=8, prefill_len=4)
    eng = Engine(params, CFG, ctx, ecfg)
    rid = eng.add_request([1, 2, 3, 4], max_new=100)  # page caps it at 4
    rid2 = eng.add_request([5, 6], max_new=2)  # must wait for the slot
    out = eng.run()
    req = eng.requests[rid]
    # prefill emits one token "for free"; each decode then burns a page
    # row until pos hits page_len
    assert len(out[rid]) == ecfg.page_len - 4 + 1
    assert req.pos == ecfg.page_len
    span = next(r for r in eng.obs.finished if r.rid == rid)
    assert span.finish_reason == "page_exhausted"
    # the evicted request's slot (the only one) was recycled for rid2
    assert eng.requests[rid2].slot == 0
    assert len(out[rid2]) == 2
    assert eng.kv.allocator.num_free == 1


def test_prefill_billing_uses_executed_width(float_model):
    """Satellite regression: prefill was billed at ``len(req.prompt)``,
    but the engine always executes a fixed ``[1, prefill_len]`` window —
    a 3-token prompt occupies the pipeline exactly as long as an 8-token
    one. Occupancy accounting now records the executed width; the span
    keeps the real prompt length for TTFT attribution."""
    params, ctx = float_model
    ecfg = EngineConfig(lanes=1, num_slots=1, page_len=16, prefill_len=8)
    reps = []
    for n in (3, 8):  # padded vs exact-width prompt
        eng = Engine(params, CFG, ctx, ecfg)
        eng.add_request(list(range(1, n + 1)), max_new=3)
        eng.run()
        pre = [e for e in eng.obs.steps if e.kind == "prefill"]
        assert [e.n_tokens for e in pre] == [ecfg.prefill_len]
        assert eng.obs.finished[0].n_prompt == n
        reps.append(eng.trace_report())
    assert reps[0].pipeline.makespan == pytest.approx(
        reps[1].pipeline.makespan
    )


def test_static_plan_optional_executed_width():
    reqs = [Request(rid=0, prompt=[1, 2], max_new=2),
            Request(rid=1, prompt=[1, 2, 3, 4], max_new=2)]
    exact = static_batching_plan(reqs, lanes=2)
    padded = static_batching_plan(reqs, lanes=2, prefill_len=8)
    assert [e for e in exact if e[0] == "prefill"] == [
        ("prefill", (0,), 2), ("prefill", (1,), 4)]
    assert [e for e in padded if e[0] == "prefill"] == [
        ("prefill", (0,), 8), ("prefill", (1,), 8)]
    assert [e for e in exact if e[0] == "decode"] == [
        e for e in padded if e[0] == "decode"]


# ------------------------------------------- satellite: paged decode path

def _paged_and_legacy_decode(params, ctx, ids, pre, t, prefill_len=12):
    """Run the serving decode path (padded fixed-shape prefill -> slot
    scatter -> gather -> per-lane-pos decode) and the legacy monolithic
    decode (unpadded prefill-into-cache, scalar pos) side by side.
    Returns per-step (paged_logits, legacy_logits) [V] arrays."""
    kv = PagedKVCache(CFG, num_slots=2, lanes=1, page_len=16)
    slot = kv.allocator.alloc()
    rows = jnp.asarray([slot], jnp.int32)
    n = pre
    pad_ids = np.zeros((1, prefill_len), np.int32)
    pad_ids[0, :n] = np.asarray(ids[0, :n])
    positions = np.full((1, prefill_len), attn_mod.KV_PAD, np.int32)
    positions[0, :n] = np.arange(n)
    caches = lm.init_cache(CFG, 1, kv.page_len)
    _, caches = lm.forward(
        params, CFG, ctx,
        {"ids": jnp.asarray(pad_ids), "positions": jnp.asarray(positions)},
        caches=caches,
    )
    kv.scatter(rows, caches)
    legacy = lm.init_cache(CFG, 1, kv.page_len)
    _, legacy = lm.forward(params, CFG, ctx, {"ids": ids[:, :pre]},
                           caches=legacy)
    out = []
    for p in range(pre, t):
        lg_p, new = lm.decode_step(
            params, CFG, ctx, ids[:, p:p + 1],
            jnp.full((1,), p, jnp.int32), kv.gather(rows),
        )
        kv.scatter(rows, new)
        lg_l, legacy = lm.decode_step(
            params, CFG, ctx, ids[:, p:p + 1], jnp.int32(p), legacy
        )
        out.append((np.asarray(lg_p, np.float32)[0],
                    np.asarray(lg_l, np.float32)[0]))
    return out


def test_paged_decode_matches_forward_logits_mxfp4(mxfp4_model):
    """Satellite: teacher-forced decode over the paged cache reproduces
    the full-sequence ``lm.forward`` logits token-for-token under the
    serving mxfp4 backend (weight-only resident MXFP4 — no activation
    quantization, so decode is length-causal and the full forward is a
    valid fixture; cf. the cim variant below)."""
    params, ctx = mxfp4_model
    t, pre = 10, 4
    ids = jax.random.randint(jax.random.PRNGKey(5), (1, t), 0, CFG.vocab_size)
    full, _ = lm.forward(params, CFG, ctx, {"ids": ids})
    full = np.asarray(full, np.float32)
    steps = _paged_and_legacy_decode(params, ctx, ids, pre, t)
    for i, (got, leg) in enumerate(steps):
        p = pre + i
        want = full[0, p]
        assert got.argmax() == want.argmax(), f"token mismatch at pos {p}"
        np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)
        np.testing.assert_array_equal(got, leg)


def test_paged_decode_matches_legacy_decode_cim(cim_model):
    """Satellite, hybrid-analog half: the paged serving decode is
    *bitwise* the legacy monolithic-cache decode, and its deviation from
    the full-sequence forward is bounded.

    Exact equality with ``lm.forward`` is unattainable for the hybrid
    SDPA by construction: the digital-MXFP4 datapath (paper §4.5)
    re-quantizes V in shared-exponent blocks along the key axis, so a
    full forward's block exponents see tokens that had not arrived when
    the decode cache froze each K/V row — appending a token perturbs
    *earlier* positions' layer>=1 hidden states (encoder-tile semantics;
    measured ~14-17 dB logit SQNR on this random-init worst case, which
    near-uniform random logits turn into occasional argmax ties)."""
    from repro.core.metrics import sqnr_db

    params, ctx = cim_model
    ctx = dataclasses.replace(ctx, unroll_layers=True)
    t, pre = 10, 4
    ids = jax.random.randint(jax.random.PRNGKey(5), (1, t), 0, CFG.vocab_size)
    full, _ = lm.forward(params, CFG, ctx, {"ids": ids})
    full = np.asarray(full, np.float32)
    steps = _paged_and_legacy_decode(params, ctx, ids, pre, t)
    agree = 0
    for i, (got, leg) in enumerate(steps):
        p = pre + i
        np.testing.assert_array_equal(
            got, leg, err_msg=f"paged != legacy decode at pos {p}"
        )
        want = full[0, p]
        assert sqnr_db(want, got) > 10.0, f"unbounded drift at pos {p}"
        agree += int(got.argmax() == want.argmax())
    assert agree >= len(steps) - 2, f"only {agree}/{len(steps)} tokens agree"


# ----------------------------------------------- sharded paged decode step

def test_make_paged_decode_step_executes(float_model):
    """The sharded serving bundle compiles and one paged step matches the
    plain (unsharded) gather -> decode -> scatter composition."""
    from repro import configs as C2
    from repro.launch import steps as steps_mod

    params, ctx = float_model
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lanes, num_slots, page = 2, 3, 8
    bundle = steps_mod.make_paged_decode_step(
        CFG, mesh, C2.Shape(page, lanes, "decode"), num_slots, quant="none"
    )
    pool = lm.init_cache(CFG, num_slots + lanes, page)
    rows = jnp.asarray([1, num_slots + 1], jnp.int32)  # lane0 slot1, lane1 parked
    ids = jax.random.randint(jax.random.PRNGKey(7), (lanes, 1), 0,
                             CFG.vocab_size)
    pos = jnp.asarray([0, 0], jnp.int32)
    # reference before the jitted call: bundle.fn donates the pool buffers
    ref_caches = gather_rows(pool, lm.cache_specs(CFG), rows)
    logits, ref_caches = lm.decode_step(
        params, CFG, bundle.ctx, ids, pos, ref_caches
    )
    ref_pool = scatter_rows(pool, lm.cache_specs(CFG), rows, ref_caches)

    next_ids, new_pool = bundle.fn(params, pool, rows, ids, pos)
    assert next_ids.shape == (lanes,)
    np.testing.assert_array_equal(
        np.asarray(next_ids),
        np.asarray(jnp.argmax(logits.astype(jnp.float32), -1), np.int32),
    )
    for seg_a, seg_b in zip(new_pool, ref_pool):
        for k in seg_a:
            np.testing.assert_array_equal(np.asarray(seg_a[k], np.float32),
                                          np.asarray(seg_b[k], np.float32))


# --------------------------------------------------- FWS pipeline fidelity

def test_pipeline_steady_state_fps_matches_table7():
    for name, n_tokens, d in (("vit-b16", 197, 768), ("bert-base", 512, 768)):
        paper_fps = S.PAPER_TABLE7[name][1]
        jobs = [pipe.Job(0.0, n_tokens) for _ in range(240)]
        rep = pipe.simulate(jobs, d_model=d)
        assert rep.steady_state_fps == pytest.approx(paper_fps, rel=0.05), name
        assert rep.steady_state_fps == pytest.approx(
            perf.steady_state_fps(n_tokens, d), rel=1e-6
        )
        # pipeline full from a deep queue -> the bottleneck stage saturates
        assert rep.stage_utilization > 0.9


def test_steady_state_fps_is_public_and_consistent():
    assert perf.steady_state_fps(197) == pytest.approx(
        1.0 / perf.stage_time(197, 768)
    )
    w = S.WORKLOADS["vit-b16"]
    assert perf.steady_state_fps(w.seq, w.d) == pytest.approx(perf.fps(w))


def test_pipeline_latency_and_warmup():
    # a single job's latency is n_stages * stage_time after an empty pipe
    rep = pipe.simulate([pipe.Job(0.0, 64)], d_model=768)
    t = perf.stage_time(64, 768)
    assert rep.timings[0].latency == pytest.approx(pipe.N_STAGES * t)
    # back-to-back jobs: one drains per stage_time in steady state
    rep = pipe.simulate([pipe.Job(0.0, 64) for _ in range(40)], d_model=768)
    drains = [x.finish for x in rep.timings]
    gaps = np.diff(drains[pipe.N_STAGES:])
    np.testing.assert_allclose(gaps, t, rtol=1e-9)


def test_trace_report_continuous_vs_static(float_model):
    params, ctx = float_model
    rng = np.random.default_rng(6)
    reqs = [
        (rng.integers(0, CFG.vocab_size, size=rng.integers(2, 8)).tolist(),
         int(rng.integers(2, 8)))
        for _ in range(6)
    ]
    eng, _, out = _staggered_run(params, ctx, reqs)
    rep = eng.trace_report()
    assert set(rep.request_latency) == set(out)
    assert all(v > 0 for v in rep.request_latency.values())
    assert 0 < rep.pipeline.stage_utilization <= 1.0
    n_tok = sum(len(v) for v in out.values())
    assert rep.tokens_per_s == pytest.approx(n_tok / rep.pipeline.makespan)

    static = pipe.simulate_trace(
        static_batching_plan(
            [Request(rid=i, prompt=p, max_new=m)
             for i, (p, m) in enumerate(reqs)], lanes=3),
        CFG.d_model, lanes=3,
    )
    # static batching wastes lanes on the tail of every group
    assert static.lane_utilization < 1.0
    assert eng.slot_utilization > static.lane_utilization


# ----------------------------------------------- simulate_trace edge cases

def test_simulate_trace_empty():
    rep = pipe.simulate_trace([], CFG.d_model, lanes=3)
    assert rep.request_latency == {}
    assert rep.tokens_per_s == 0.0
    assert rep.pipeline.makespan == 0.0
    assert rep.pipeline.bubble_fraction == 1.0
    assert rep.pipeline.fill_latency_s == 0.0
    assert rep.lane_utilization == 1.0  # no decode steps -> vacuous


def test_simulate_trace_single_event():
    rep = pipe.simulate_trace([("prefill", (0,), 8)], CFG.d_model, lanes=3)
    assert set(rep.request_latency) == {0}
    # one job alone: latency == full pipe traversal == fill latency
    assert rep.request_latency[0] == pytest.approx(
        rep.pipeline.fill_latency_s
    )
    assert rep.tokens_per_s > 0


def test_simulate_trace_evicted_before_finish():
    # rid 1 is evicted after one decode step (no further events); its
    # latency still closes at the drain of the last job that carried it
    events = [
        ("prefill", (0,), 8),
        ("prefill", (1,), 8),
        ("decode", (0, 1), 2),
        ("decode", (0,), 1),
        ("decode", (0,), 1),
    ]
    rep = pipe.simulate_trace(events, CFG.d_model, lanes=3)
    assert set(rep.request_latency) == {0, 1}
    assert rep.request_latency[1] < rep.request_latency[0]
    assert all(v > 0 for v in rep.request_latency.values())


def test_simulate_trace_accepts_step_events():
    from repro.obs import StepEvent

    tuples = [("prefill", (0,), 4), ("decode", (0,), 1)]
    typed = [StepEvent(k, r, n, 0.0, 0.0) for k, r, n in tuples]
    a = pipe.simulate_trace(tuples, CFG.d_model, lanes=2)
    b = pipe.simulate_trace(typed, CFG.d_model, lanes=2)
    assert a.request_latency == b.request_latency
    assert a.tokens_per_s == b.tokens_per_s


# ------------------------------------------------------- engine telemetry

def test_engine_emits_request_spans_and_metrics(float_model):
    from repro import obs as obs_lib

    params, ctx = float_model
    rng = np.random.default_rng(7)
    reqs = [
        (rng.integers(0, CFG.vocab_size, size=rng.integers(2, 8)).tolist(),
         int(rng.integers(2, 6)))
        for _ in range(4)
    ]
    eng, _, out = _staggered_run(params, ctx, reqs)
    o = eng.obs
    # every request finished with a full span: ttft < e2e, tokens counted
    assert len(o.finished) == len(reqs)
    for r in o.finished:
        assert r.t_admitted is not None and r.ttft_s > 0
        assert r.e2e_s >= r.ttft_s
        assert r.n_generated == len(out[r.rid])
    # the derived legacy view matches Engine.trace and feeds the pipeline
    assert eng.trace == o.legacy_trace()
    assert {e.kind for e in o.steps} == {"prefill", "decode"}
    reg = o.registry
    assert reg.counter("serve_requests_total").value == len(reqs)
    assert reg.histogram("serve_ttft_seconds").count == len(reqs)
    n_tok = sum(len(v) for v in out.values())
    assert reg.counter("serve_tokens_generated_total").value == n_tok
    finished = reg.counter("serve_requests_finished_total",
                           labels={"reason": "max_new"})
    assert finished.value == len(reqs)
    # trace_report still works off the typed record
    rep = eng.trace_report()
    assert set(rep.request_latency) == set(out)


def test_engine_disabled_obs_matches_default_trace(float_model):
    from repro import obs as obs_lib

    params, ctx = float_model
    ecfg = EngineConfig(lanes=2, num_slots=2, page_len=16, prefill_len=8)
    prompt = [3, 1, 4, 1, 5]
    eng_on = Engine(params, CFG, ctx, ecfg)
    eng_off = Engine(params, CFG, ctx, ecfg,
                     obs=obs_lib.Obs(enabled=False))
    for eng in (eng_on, eng_off):
        eng.add_request(list(prompt), max_new=3)
    assert eng_on.run()[0] == eng_off.run()[0]
    # the step record (pipeline-model input) is identical either way...
    assert eng_off.trace == eng_on.trace
    assert eng_off.slot_utilization == eng_on.slot_utilization
    # ...but the disabled side did no registry or span work
    assert eng_off.obs.registry.families() == []
    assert eng_off.obs.finished == []
    assert len(eng_on.obs.finished) == 1
